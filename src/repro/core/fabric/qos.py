"""QoS traffic classes for the fabric timeline — virtual channels with
class-weighted arbitration and partitioned credits.

The APEnet+ router arbitrates several traffic sources onto each torus
link with dedicated per-channel resources (arXiv:1102.3796 §2; the 28 nm
follow-up extends the switch/arbiter datapath).  On the shared serving +
training fabric this repo models, that hardware fact is what makes
co-location viable: a bulk KV-page migration must not be able to starve
the latency-critical decode-step collectives it shares links with.

This module defines the *policy* half of the subsystem; the mechanism (a
per-class virtual-channel queue on every directed link, drained by a
weighted arbiter with per-class credit partitions) lives in
``fabric.sim.FabricSim``.

  ``TrafficClass``  — who is sending: ``CONTROL`` (descriptors, LO|FA|MO
                      diagnostics), ``DECODE`` (serving per-step tensor-
                      parallel collectives), ``COLLECTIVE`` (trainer
                      gradient buckets), ``BULK`` (KV-page migration,
                      checkpoint streams).
  ``QosPolicy``     — per-class arbitration weight (bandwidth share under
                      contention is weight-proportional) and per-class
                      fraction of each link's ~40 KB credit pool, so one
                      class's backpressure can never exhaust another's
                      credits.

``QosPolicy(single_class=True)`` collapses every class onto ONE virtual
channel with the whole credit pool — exactly the pre-QoS FIFO link, kept
as a config so the sim/analytic differential (and any consumer that wants
the old behaviour) reproduces those results bitwise.  ``FabricSim``
defaults to it.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Mapping


class TrafficClass(enum.IntEnum):
    """Fabric traffic classes, one virtual channel each (arbiter order is
    by enum value only for deterministic tie-breaks, not priority)."""

    CONTROL = 0      # RDMA GET descriptors, sync/diagnostic messages
    DECODE = 1       # serving decode-step TP collectives (latency-critical)
    COLLECTIVE = 2   # trainer gradient buckets / bulk collectives
    BULK = 3         # KV-page migration, checkpoint and data streams


# Default arbitration weights: under contention a class's share of a
# saturated link is weight / sum(weights of backlogged classes).  DECODE
# at 16x BULK bounds the decode stretch under full bulk interference at
# ~17/16 (< the 1.10x acceptance bar); CONTROL is tiny traffic that must
# never queue behind payloads; COLLECTIVE sits between.
DEFAULT_WEIGHTS: dict[TrafficClass, float] = {
    TrafficClass.CONTROL: 4.0,
    TrafficClass.DECODE: 16.0,
    TrafficClass.COLLECTIVE: 8.0,
    TrafficClass.BULK: 1.0,
}

# Default credit partition: fraction of each link's credit pool (the
# ~40 KB bandwidth-delay product, ``apelink.channel_footprint_bytes``)
# reserved per class.  A congested BULK flow can fill at most its own
# partition of a downstream buffer — DECODE's window survives untouched.
DEFAULT_CREDIT_FRAC: dict[TrafficClass, float] = {
    TrafficClass.CONTROL: 0.10,
    TrafficClass.DECODE: 0.40,
    TrafficClass.COLLECTIVE: 0.30,
    TrafficClass.BULK: 0.20,
}


@dataclasses.dataclass(frozen=True)
class QosPolicy:
    """Arbitration weights + credit partition for the link virtual channels.

    ``weights``/``credit_frac`` may list any subset of ``TrafficClass``;
    unlisted classes keep their defaults.  ``single_class=True`` ignores
    both and reproduces the pre-QoS FIFO link exactly (one channel, one
    undivided credit pool) — the backwards-compatibility config the
    sim/analytic differential runs under.
    """

    weights: Mapping[TrafficClass, float] = dataclasses.field(
        default_factory=dict)
    credit_frac: Mapping[TrafficClass, float] = dataclasses.field(
        default_factory=dict)
    single_class: bool = False

    def __post_init__(self) -> None:
        for name, mapping, defaults in (
                ("weights", self.weights, DEFAULT_WEIGHTS),
                ("credit_frac", self.credit_frac, DEFAULT_CREDIT_FRAC)):
            merged = dict(defaults)
            for k, v in dict(mapping).items():
                k = TrafficClass(k)
                if v <= 0:
                    raise ValueError(
                        f"{name}[{k.name}] must be > 0, got {v}")
                merged[k] = float(v)
            object.__setattr__(self, name, merged)

    # -- class identity -------------------------------------------------------
    @property
    def n_classes(self) -> int:
        """Virtual channels per link (1 when single_class)."""
        return 1 if self.single_class else len(TrafficClass)

    def class_index(self, cls: TrafficClass | int | None) -> int:
        """Virtual-channel index of a traffic class under this policy."""
        if self.single_class or cls is None:
            return 0
        return int(TrafficClass(cls))

    # -- arbiter parameters ---------------------------------------------------
    def weight_vector(self) -> tuple[float, ...]:
        """Per-channel arbitration weights, channel-index order."""
        if self.single_class:
            return (1.0,)
        return tuple(self.weights[c] for c in TrafficClass)

    def partition_credits(self, total: float) -> tuple[float, ...]:
        """Split one link's credit pool across the virtual channels.

        Fractions are normalized so the partitions always sum to the full
        pool; ``single_class`` keeps it undivided."""
        if self.single_class:
            return (float(total),)
        fracs = [self.credit_frac[c] for c in TrafficClass]
        norm = sum(fracs)
        return tuple(float(total) * f / norm for f in fracs)

    def describe(self) -> str:
        if self.single_class:
            return "QosPolicy(single_class=True): one FIFO channel"
        lines = ["QosPolicy: weight / credit fraction per class"]
        norm = sum(self.credit_frac[c] for c in TrafficClass)
        for c in TrafficClass:
            lines.append(f"  {c.name:<10s} w={self.weights[c]:g} "
                         f"credit={self.credit_frac[c] / norm:.2%}")
        return "\n".join(lines)


#: The legacy configuration: every flow on one FIFO virtual channel with
#: the whole credit pool — bitwise the pre-QoS ``FabricSim``.
SINGLE_CLASS = QosPolicy(single_class=True)
