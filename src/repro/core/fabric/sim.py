"""Discrete-event, link-level fabric simulator — the shared timeline every
time model in the repo can price against.

The analytic estimator (``fabric.cost``) prices each transfer in isolation:
one ``NetModel.latency`` message over its hops.  That is exact for a single
flow, but APElink is a *shared-resource* design — per-channel credit-based
flow control with a ~40 KB footprint (paper §2.3) and dimension-ordered
routing over links that many in-flight packets compete for.  The companion
works arXiv:1102.3796 and arXiv:1307.8276 measure exactly that regime:
aggregate traffic on shared links.  ``FabricSim`` closes the gap:

  * every directed first-neighbour link carries one **virtual channel per
    traffic class** (``fabric.qos.TrafficClass``) at the APElink sustained
    payload bandwidth; a weighted arbiter (start-time-fair virtual-time
    scheduling — the router's class-weighted round-robin) drains the
    channels, so under contention each backlogged class holds a
    weight-proportional share of the link and no class can be starved.
    The default ``QosPolicy(single_class=True)`` collapses this to ONE
    FIFO channel — bitwise the pre-QoS simulator;
  * **credit-based flow control**: each directed link's downstream buffer
    holds ``credit_bytes`` (default: ``apelink.channel_footprint_bytes`` —
    the paper's ~40 KB bandwidth-delay product), partitioned per class by
    the ``QosPolicy``.  A packet only starts crossing a link when its
    class's partition of the far buffer has room; credits return when the
    packet leaves that buffer (consumed at the endpoint, or started on the
    next link).  Congestion therefore backpressures upstream hop by hop —
    but only within its own class: a saturated BULK stream cannot exhaust
    DECODE's credits;
  * **dimension-ordered packet walks**: a flow's route defaults to
    ``Torus.route`` (X then Y then Z), or the BFS detour over the
    surviving graph under a ``FaultMap`` — the same one BFS the lowering
    and fault-rewrite layers use (``lower._bfs_path``);
  * endpoint costs match the analytic model: ``t_inject`` before the first
    link, ``t_receive`` after the last, ``t_hop`` per router transit, GPU
    touch overheads and the GPU-outbound read cap as source pacing.

Consumers:

  * ``fabric.estimate(..., backend="sim")`` — ``simulate_schedule`` walks a
    ``CollectiveSchedule`` round by round (each round's flows barrier on
    the previous round, exactly the analytic model's sequential-rounds
    rule), so the sim and the analytic estimate must agree on single-flow
    schedules — that differential validates both models;
  * ``RdmaEndpoint`` (``sim=`` attached) — ``put_pages``/``get_time``
    inject their DMA drain (a host-interface FIFO resource per rank) and
    wire legs as flows instead of summing closed-form terms; bulk PUTs
    ride the BULK class, GET descriptors ride CONTROL;
  * ``ServingCluster``/``Engine`` — one cluster-wide sim; decode-step TP
    collectives (DECODE class) and migration PUTs (BULK) ride the same
    links and contend — by policy, not free-for-all;
  * ``ServingCluster.migrate`` — congestion-aware path selection probes
    candidate routes (``candidate_routes``, the fault BFS machinery) by
    simulated completion time; ``striped_routes`` splits one bulk
    transfer across the k best candidates (multi-path striping).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Hashable, Sequence

from repro.core import apelink
from repro.core.apelink import NetModel
from repro.core.fabric.cost import CostEstimate
from repro.core.fabric.lower import UnroutableError, _bfs_path, _lanes
from repro.core.fabric.qos import SINGLE_CLASS, QosPolicy, TrafficClass
from repro.core.fabric.schedule import (
    P2P, CollectiveSchedule, FaultMap, Phase, Transfer)
from repro.core.fabric.telemetry import ordered_link_items
from repro.core.topology import Torus

# flows bigger than max_packets * packet_bytes coarsen their packets so the
# event count stays bounded — up to the credit constraint: a packet must
# fit its class's credit window (a packet larger than the far buffer could
# never be granted credit), so under a multi-class policy coarsening stops
# at half the class's credit partition and a bulk flow's event count is
# bounded by nbytes / (partition / 2) instead of max_packets.  That is the
# price of partitioned virtual-channel buffers (real VC routers have the
# same packet-size bound); sims that only need FIFO semantics keep the
# default single-class policy and the full-pool cap.
DEFAULT_PACKET_BYTES = 4096
DEFAULT_MAX_PACKETS = 256


def link_key(torus: Torus, u: int, v: int, channel: int) -> tuple:
    """Physical cable identity of the hop u -> v.

    Every node wires BOTH ports of each dimension (6 links per node on a
    3D torus), so the +1 and -1 traversal directions are distinct cables
    even when they join the same rank pair — which happens exactly on
    2-rings, where the dual-DMA round's two transfers ride the two
    parallel cables concurrently (the analytic model's disjoint-directions
    rule).  For rings > 2 the direction is implied by the coordinates; on
    a 2-ring the flow's ``channel`` hint disambiguates.  Shared by the
    packet tier (``FabricSim``) and the fluid tier (``fluid.FluidSim``) so
    both fidelity tiers agree on what "one link" is.
    """
    cu, cv = torus.coords(u), torus.coords(v)
    for d, (a, b) in enumerate(zip(cu, cv)):
        if a != b:
            n = torus.dims[d]
            if n == 2:
                return (u, v, channel & 1)
            return (u, v, 0 if (b - a) % n == 1 else 1)
    return (u, v, 0)   # self-link (unused)


def packetize(nbytes: float, cap: float, packet_bytes: float,
              max_packets: int) -> tuple[float, int]:
    """Packet size/count for a flow whose class credit partition is
    ``cap`` — a packet larger than its partition could never be granted
    credit.  The fluid tier reuses this to derive its per-flow arbiter
    weight and store-and-forward tail, so the two tiers price the same
    packetization."""
    if nbytes <= 0:
        return 0.0, 1
    pkt = float(min(packet_bytes, int(cap) or 1))
    npkts = -(-nbytes // pkt)
    if npkts > max_packets:
        pkt = min(nbytes / max_packets, cap)
    return pkt, int(-(-nbytes // pkt))


# ----------------------------------------------------------------------------
# fault-epoch route caching
#
# Repeated probes, re-striping and schedule injections recompute identical
# BFS detours: the fault map only changes at fault *epochs* (fail_link /
# clear_faults), yet every ``probe_route`` -> ``candidate_routes`` walk and
# every fault-routed ``inject`` re-ran the BFS from scratch.  Both caches
# key on the full (torus dims, src, dst, FaultMap) value — FaultMap is a
# frozen dataclass of frozensets, so a *new* epoch is a new key and stale
# hits are impossible; ``clear_route_cache`` (called by the serving
# cluster's fail_link/clear_faults) drops the dead epoch's entries so the
# caches stay bounded by the live epoch's working set.
# ----------------------------------------------------------------------------

_ROUTE_CACHE_CAP = 65536
_bfs_cache: dict = {}
_candidates_cache: dict = {}
_MISS = object()

# Cumulative hit/miss tallies for the module-level route caches.  The
# caches are free functions shared by every sim, so their stats live
# here; a ``Telemetry`` hub copies them in as gauges on an explicit
# ``collect()`` — never on the hot path, so probe-invariance tests stay
# clean.  Plain int increments: invisible to any replay metric.
ROUTE_CACHE_STATS = {"bfs_hits": 0, "bfs_misses": 0,
                     "cand_hits": 0, "cand_misses": 0}


def clear_route_cache() -> None:
    """Invalidate the per-fault-epoch route caches (BFS paths and
    candidate detour families).  Callers that mutate the fault world —
    ``ServingCluster.fail_link``/``clear_faults`` — invoke this so the
    previous epoch's entries are released."""
    _bfs_cache.clear()
    _candidates_cache.clear()


def _cached_bfs(torus: Torus, src: int, dst: int,
                faults: FaultMap) -> list[int] | None:
    key = (torus.dims, src, dst, faults)
    hit = _bfs_cache.get(key, _MISS)
    if hit is _MISS:
        ROUTE_CACHE_STATS["bfs_misses"] += 1
        if len(_bfs_cache) >= _ROUTE_CACHE_CAP:
            _bfs_cache.clear()
        hit = _bfs_cache[key] = _bfs_path(torus, src, dst, faults)
    else:
        ROUTE_CACHE_STATS["bfs_hits"] += 1
    return hit


class _Link:
    """One directed link (or host-IF resource): per-class virtual-channel
    FIFOs + partitioned credit windows, drained by the weighted arbiter."""

    __slots__ = ("free_at", "queues", "credits", "vtime", "vfloor",
                 "busy_s", "bytes_carried", "class_bytes", "retry_at")

    def __init__(self, credits: Sequence[float]) -> None:
        self.free_at = 0.0
        self.queues = tuple([] for _ in credits)  # per-class FIFO of _Pkt
        self.credits = list(credits)  # downstream buffer bytes, per class
        # start-time-fair arbiter state: a class's virtual time advances by
        # cost/weight per service; the backlogged class with the least
        # virtual time transmits next (single class: always channel 0)
        self.vtime = [0.0 for _ in credits]
        self.vfloor = 0.0            # service frontier for re-activations
        self.busy_s = 0.0
        self.bytes_carried = 0.0
        # carried bytes per traffic-class TAG (not per channel): stays
        # meaningful under single_class, where every tag shares channel 0
        self.class_bytes = [0.0] * len(TrafficClass)
        self.retry_at: float | None = None   # pending retry event (dedup)


class _Pkt:
    __slots__ = ("fid", "idx", "hop", "nbytes", "prev", "route")

    def __init__(self, fid: int, idx: int, hop: int, nbytes: float,
                 prev: tuple | None,
                 route: tuple[int, ...] = ()) -> None:
        self.fid = fid
        self.idx = idx           # packet index within the flow
        self.hop = hop           # index of the link being traversed
        self.nbytes = nbytes
        self.prev = prev         # upstream link key owed a credit return
        # per-packet route tag: the flow's route at FEED time.  In-flight
        # packets keep walking the path they were launched on even after
        # ``restripe`` re-points the flow, so a half-sent striped PUT can
        # re-split its unsent remainder without corrupting the packets
        # already committed to the old path (§2.1's per-packet header
        # routing, as opposed to per-flow circuit state).
        self.route = route


class _Flow:
    __slots__ = ("fid", "route", "nbytes", "pkt_bytes", "npkts", "sent",
                 "arrived", "req_start", "start_s", "finish_s", "pending",
                 "dependents", "src_over", "dst_over", "pace_s", "service_s",
                 "resource", "channel", "label", "cls", "cidx")

    def __init__(self, fid: int) -> None:
        self.fid = fid
        self.route: tuple[int, ...] = ()
        self.nbytes = 0.0
        self.pkt_bytes = 0.0
        self.npkts = 0
        self.sent = 0
        self.arrived = 0
        self.req_start = 0.0
        self.start_s: float | None = None
        self.finish_s: float | None = None
        self.pending = 0                 # unfinished dependencies
        self.dependents: list[int] = []
        self.src_over = 0.0
        self.dst_over = 0.0
        self.pace_s = 0.0                # source pacing gap (GPU read cap)
        self.service_s: float | None = None   # resource occupancy duration
        self.resource: Hashable | None = None
        self.channel = 0                 # cable pick on 2-rings (see below)
        self.label = ""
        self.cls: TrafficClass | None = None  # traffic class tag
        self.cidx = 0                    # virtual-channel index under policy


class _Journal:
    """Copy-on-write undo log for ``probe_route``: instead of snapshotting
    the whole sim up front, the probe records each link/flow/packet's
    state the FIRST time the ghost traffic touches it.  Rolling back
    therefore costs O(state the probe actually perturbed) — the candidate
    route's links plus the flows crossing them — not O(resident sim),
    which is the difference between O(k · route) and O(k · cluster) when
    probing k candidates on a 512-node serving timeline."""

    __slots__ = ("links", "flows", "pkts", "heap", "frontier", "seq_n",
                 "fid_n", "stale")

    def __init__(self, heap: list, frontier: float, seq_n: int, fid_n: int,
                 stale: int) -> None:
        self.links: dict = {}        # key -> saved field tuple | None (new)
        self.flows: dict = {}        # fid -> saved mutable fields
        self.pkts: dict = {}         # id(pkt) -> (pkt, hop, prev)
        self.heap = heap             # heap list copied eagerly (events are
        self.frontier = frontier     # tuples; mutable pkts inside are
        self.seq_n = seq_n           # journalled at their mutation site)
        self.fid_n = fid_n
        self.stale = stale


@dataclasses.dataclass(frozen=True)
class FlowResult:
    """Completed flow, as reported by ``FabricSim.flow``."""

    fid: int
    src: int
    dst: int
    nbytes: float
    hops: int
    start_s: float
    finish_s: float
    label: str = ""
    cls: TrafficClass | None = None

    @property
    def duration_s(self) -> float:
        return self.finish_s - self.start_s

    @property
    def bandwidth(self) -> float:
        d = self.duration_s
        return self.nbytes / d if d > 0 else float("inf")


class FabricSim:
    """Event-driven link-level simulator over one ``Torus`` fabric.

    Flows are injected (``inject`` for wire transfers, ``occupy`` for
    rank-local host-interface DMA occupancy), optionally chained with
    ``after=`` and tagged with a ``TrafficClass``; ``run()`` drains the
    event queue.  ``qos`` selects the link arbiter: the default
    ``QosPolicy(single_class=True)`` is the classic single-FIFO link
    (class tags are inert); a multi-class ``QosPolicy()`` gives every
    class its own virtual channel, weight-proportional bandwidth under
    contention and a private credit partition.  The clock only moves
    forward: ``now`` is the frontier, and a timeline owner (the serving
    cluster) can ``advance`` it between logical windows.  Injecting at a
    time the simulator already processed is allowed but conservative —
    the new packets queue behind whatever the links already committed to.
    """

    def __init__(self, torus: Torus, net: NetModel | None = None, *,
                 packet_bytes: int = DEFAULT_PACKET_BYTES,
                 credit_bytes: float | None = None,
                 max_packets_per_flow: int = DEFAULT_MAX_PACKETS,
                 faults: FaultMap | None = None,
                 qos: QosPolicy | None = None,
                 telemetry: "object | None" = None) -> None:
        if packet_bytes <= 0:
            raise ValueError(f"packet_bytes must be > 0, got {packet_bytes}")
        self.torus = torus
        self.net = net or NetModel()
        self.faults = faults or FaultMap()
        self.qos = qos or SINGLE_CLASS
        self.link_bw = apelink.sustained_bandwidth(self.net.link)
        self.credit_bytes = (float(credit_bytes) if credit_bytes is not None
                             else apelink.channel_footprint_bytes(
                                 self.net.link))
        if self.credit_bytes <= 0:
            raise ValueError("credit_bytes must be > 0")
        self.packet_bytes = min(packet_bytes, int(self.credit_bytes) or 1)
        self.max_packets = max(1, max_packets_per_flow)
        self._weights = self.qos.weight_vector()
        self._class_credits = self.qos.partition_credits(self.credit_bytes)
        self._links: dict = {}
        self._flows: dict[int, _Flow] = {}
        self._heap: list = []
        self._seq_n = 0          # event tie-break counter (plain int so
        self._fid_n = 0          # probe snapshots can restore it exactly)
        self._frontier = 0.0
        self._stale = 0          # superseded retry events still in the heap
        self._journal: _Journal | None = None   # active probe journal
        self.last_probe_report: dict | None = None
        self.deadlock_breaks = 0   # escape-credit recoveries (see _unstick)
        # optional Telemetry hub.  Every hook is gated on
        # ``telemetry is not None and self._journal is None``: None is
        # bitwise-invisible, and probe ghosts never reach the hub.  All
        # derived telemetry state lives hub-side, so attaching one
        # changes NOTHING about sim state, snapshots, or rollbacks.
        self.telemetry = telemetry

    # -- clock ----------------------------------------------------------------
    @property
    def now(self) -> float:
        """The timeline frontier (latest processed/advanced time)."""
        return self._frontier

    def advance(self, t: float) -> None:
        """Move the frontier forward (never backward) — the timeline
        owner's logical-window boundary."""
        self._frontier = max(self._frontier, t)

    # -- link identity --------------------------------------------------------
    def _link_key(self, u: int, v: int, channel: int) -> tuple:
        """Physical cable identity of the hop u -> v (see ``link_key``)."""
        return link_key(self.torus, u, v, channel)

    # -- injection ------------------------------------------------------------
    def _resolve_route(self, src: int, dst: int,
                       route: Sequence[int] | None) -> tuple[int, ...]:
        if route is not None:
            route = tuple(route)
            if len(route) < 1 or route[0] != src or route[-1] != dst:
                raise ValueError(f"route {route} does not join {src}->{dst}")
            return route
        if src == dst:
            return (src,)
        if not self.faults:
            return tuple(self.torus.route(src, dst))
        path = _cached_bfs(self.torus, src, dst, self.faults)
        if path is None:
            raise UnroutableError(
                f"no surviving route {src} -> {dst} in the simulated fabric")
        return tuple(path)

    def _packetize(self, nbytes: float, cap: float) -> tuple[float, int]:
        """Packet size/count for a flow whose class credit partition is
        ``cap`` (see module-level ``packetize``)."""
        return packetize(nbytes, cap, self.packet_bytes, self.max_packets)

    def _new_flow(self, start_s: float | None,
                  after: Sequence[int]) -> _Flow:
        f = _Flow(self._fid_n)
        self._fid_n += 1
        f.req_start = self._frontier if start_s is None else float(start_s)
        self._flows[f.fid] = f
        for dep_fid in after:
            dep = self._flows[dep_fid]
            if dep.finish_s is None:
                self._j_flow(dep)
                dep.dependents.append(f.fid)
                f.pending += 1
            else:
                f.req_start = max(f.req_start, dep.finish_s)
        if f.pending == 0:
            self._push(f.req_start, "start", f.fid)
        return f

    def inject(self, src: int, dst: int, nbytes: float, *,
               start_s: float | None = None,
               route: Sequence[int] | None = None,
               after: Sequence[int] = (),
               src_gpu: bool = False, dst_gpu: bool = False,
               channel: int = 0, label: str = "",
               cls: TrafficClass = TrafficClass.BULK) -> int:
        """Inject one flow of ``nbytes`` from rank ``src`` to ``dst``.

        ``route`` overrides the dimension-ordered (or fault-BFS) default;
        ``after`` lists flow ids that must finish first; ``channel`` picks
        the cable on ambiguous 2-ring hops (see ``_link_key``); ``cls``
        tags the flow's traffic class (inert under a single-class policy).
        Returns the flow id — query its completion with
        ``finish_s``/``flow`` after ``run()``.
        """
        f = self._new_flow(start_s, after)
        f.route = self._resolve_route(src, dst, route)
        f.channel = channel
        f.cls = TrafficClass(cls)
        f.cidx = self.qos.class_index(f.cls)
        f.nbytes = float(nbytes)
        cap = self._class_credits[f.cidx]
        if not self.qos.single_class:
            # keep >= 2 packets inside the class's credit window: a packet
            # as large as the whole partition leaves the channel credit-
            # blocked at every arbitration instant (credits return one
            # t_hop after transmit), handing lower-weight classes a slot
            # they haven't earned
            cap = max(cap * 0.5, 1.0)
        f.pkt_bytes, f.npkts = self._packetize(f.nbytes, cap)
        f.src_over = self.net.t_inject \
            + (self.net.gpu_touch_overhead if src_gpu else 0.0)
        f.dst_over = self.net.t_receive \
            + (self.net.gpu_touch_overhead if dst_gpu else 0.0)
        if src_gpu and self.net.gpu_read_cap < self.link_bw:
            # GPU-outbound read bottleneck (Fig 3c): the source cannot feed
            # the link faster than the P2P read rate
            f.pace_s = f.pkt_bytes / self.net.gpu_read_cap
        f.label = label
        return f.fid

    def occupy(self, resource: Hashable, busy_s: float, *,
               start_s: float | None = None,
               after: Sequence[int] = (), label: str = "",
               cls: TrafficClass = TrafficClass.BULK) -> int:
        """Occupy a rank-local FIFO resource (e.g. ``("hostif", rank)``)
        for ``busy_s`` seconds — the host-interface DMA drain of one
        operation.  Concurrent occupiers of the same resource serialize;
        under a multi-class policy the arbiter weighs occupiers of
        different classes by their service seconds."""
        if busy_s < 0:
            raise ValueError(f"negative busy_s {busy_s}")
        f = self._new_flow(start_s, after)
        f.resource = resource
        f.service_s = float(busy_s)
        f.npkts = 1
        f.label = label
        f.cls = TrafficClass(cls)
        f.cidx = self.qos.class_index(f.cls)
        return f.fid

    # -- event machinery ------------------------------------------------------
    def _push(self, t: float, kind: str, arg) -> None:
        heapq.heappush(self._heap, (t, self._seq_n, kind, arg))
        self._seq_n += 1
        if self._stale > 64 and self._stale * 2 > len(self._heap) \
                and self._journal is None:
            self._compact()

    def _compact(self) -> None:
        """Drop provably superseded retry events (an earlier wake than the
        link's pending ``retry_at`` is a ghost: when popped it finds the
        link still busy and no-ops).  Long workloads with same-instant
        credit returns accumulate these; compacting lazily once they
        exceed half the heap keeps the heap bounded by live events
        without changing any processing order."""
        live = []
        for ev in self._heap:
            if ev[2] == "retry":
                link = self._links.get(ev[3])
                if link is not None and link.retry_at is not None \
                        and ev[0] < link.retry_at:
                    continue
            live.append(ev)
        self._heap = live
        heapq.heapify(live)
        self._stale = 0

    def _link(self, key) -> _Link:
        link = self._links.get(key)
        j = self._journal
        if j is not None and key not in j.links:
            # first touch under an active probe: record the pre-image
            j.links[key] = None if link is None else (
                link.free_at, tuple(list(q) for q in link.queues),
                list(link.credits), list(link.vtime), link.vfloor,
                link.busy_s, link.bytes_carried, list(link.class_bytes),
                link.retry_at)
        if link is None:
            link = self._links[key] = _Link(self._class_credits)
        return link

    def _j_flow(self, f: _Flow) -> None:
        """Journal a pre-existing flow's mutable fields on first touch."""
        j = self._journal
        if j is not None and f.fid < j.fid_n and f.fid not in j.flows:
            j.flows[f.fid] = (f.sent, f.arrived, f.req_start, f.start_s,
                              f.finish_s, f.pending, list(f.dependents))

    def _j_pkt(self, p: _Pkt) -> None:
        """Journal a pre-existing packet's routing fields on first touch."""
        j = self._journal
        if j is not None and p.fid < j.fid_n and id(p) not in j.pkts:
            j.pkts[id(p)] = (p, p.hop, p.prev)

    def _enqueue(self, key, pkt: _Pkt, now: float) -> None:
        link = self._link(key)
        q = link.queues[self._flows[pkt.fid].cidx]
        if not q:
            # re-activation joins at the service frontier, so an idle class
            # cannot bank virtual time and then monopolize the link
            c = self._flows[pkt.fid].cidx
            link.vtime[c] = max(link.vtime[c], link.vfloor)
        q.append(pkt)
        self._try_start(key, now)

    def _pick(self, link: _Link) -> int | None:
        """The backlogged virtual channel that transmits next: least
        virtual time among channels whose head packet has credit (ties
        break toward the lowest class index).  None = every backlogged
        channel is credit-blocked."""
        best = -1
        best_v = 0.0
        for c, q in enumerate(link.queues):
            if not q:
                continue
            pkt = q[0]
            if pkt.nbytes > link.credits[c] \
                    and self._flows[pkt.fid].resource is None:
                continue   # this channel is blocked until credit returns
            v = link.vtime[c]
            if best < 0 or v < best_v:
                best, best_v = c, v
        return None if best < 0 else best

    def _try_start(self, key, now: float) -> None:
        link = self._link(key)
        while any(link.queues):
            if link.free_at > now:
                # one pending retry per link: re-pushing at the same (or a
                # later) wake time only duplicates work the scheduled one
                # will do anyway
                if link.retry_at is None or link.retry_at > link.free_at \
                        or link.retry_at <= now:
                    if link.retry_at is not None:
                        # the old retry event is now a superseded ghost
                        # still sitting in the heap — count it so
                        # ``_compact`` knows when ghosts dominate
                        self._stale += 1
                    self._push(link.free_at, "retry", key)
                    link.retry_at = link.free_at
                return
            c = self._pick(link)
            if c is None:
                tel = self.telemetry
                if tel is not None and self._journal is None:
                    tel.on_credit_block(key, now)
                return   # all backlogged channels credit-blocked
            pkt: _Pkt = link.queues[c].pop(0)
            flow = self._flows[pkt.fid]
            is_resource = flow.resource is not None
            if is_resource:
                dur = flow.service_s or 0.0
                cost = dur       # seconds-unit fairness on resource links
            else:
                link.credits[c] -= pkt.nbytes
                dur = pkt.nbytes / self.link_bw
                cost = pkt.nbytes
            # start-time-fair accounting (a no-op under single_class)
            link.vfloor = max(link.vfloor, link.vtime[c])
            link.vtime[c] += cost / self._weights[c]
            start = max(link.free_at, now)
            link.free_at = start + dur
            link.busy_s += dur
            link.bytes_carried += pkt.nbytes
            link.class_bytes[int(flow.cls)] += pkt.nbytes
            tel = self.telemetry
            if tel is not None and self._journal is None:
                # mirrors the three += above in the same order, so the
                # hub's per-key counters cross-check EXACTLY
                tel.on_link_tx(key, int(flow.cls), pkt.nbytes, dur,
                               start, is_resource)
            if pkt.prev is not None:
                # the packet left the upstream buffer: credit flows back
                up = self._link(pkt.prev)
                up.credits[c] += pkt.nbytes
                self._try_start(pkt.prev, now)
            if is_resource:
                self._push(link.free_at, "done", pkt)
                continue
            if pkt.hop == 0 and flow.sent < flow.npkts:
                self._feed_source(flow, start)
            self._push(link.free_at + self.net.t_hop, "arrive", pkt)

    def _feed_source(self, flow: _Flow, now: float) -> None:
        """Queue the flow's next packet at the first link.

        One packet per flow sits at the link head at a time, so each
        virtual channel round-robins its concurrent flows at packet
        granularity; ``pace_s`` throttles GPU-outbound sources."""
        self._j_flow(flow)
        idx = flow.sent
        flow.sent += 1
        last = flow.npkts - 1
        nbytes = (flow.nbytes - last * flow.pkt_bytes) if idx == last \
            else flow.pkt_bytes
        pkt = _Pkt(flow.fid, idx, 0, max(nbytes, 0.0), None, flow.route)
        ready = (flow.start_s or 0.0) + flow.src_over + idx * flow.pace_s
        key = self._link_key(pkt.route[0], pkt.route[1], flow.channel)
        if ready > now:
            self._push(ready, "enqueue", (key, pkt))
        else:
            self._enqueue(key, pkt, now)

    def _finish_flow(self, flow: _Flow, t: float) -> None:
        self._j_flow(flow)
        flow.finish_s = t
        self._frontier = max(self._frontier, t)
        tel = self.telemetry
        if tel is not None and self._journal is None:
            start = flow.start_s if flow.start_s is not None \
                else flow.req_start
            if flow.resource is not None:
                track = ("node", flow.resource)
            elif len(flow.route) >= 2:
                track = ("link", self._link_key(flow.route[0],
                                                flow.route[1],
                                                flow.channel))
            else:
                track = ("node", flow.route[0] if flow.route else -1)
            tel.flow_span(track, flow.label or f"flow{flow.fid}",
                          start, t, cls=int(flow.cls),
                          nbytes=flow.nbytes, fid=flow.fid)
        for dep_fid in flow.dependents:
            dep = self._flows[dep_fid]
            self._j_flow(dep)
            dep.pending -= 1
            dep.req_start = max(dep.req_start, t)
            if dep.pending == 0:
                self._push(dep.req_start, "start", dep.fid)
        flow.dependents = []

    def _start_flow(self, flow: _Flow, now: float) -> None:
        self._j_flow(flow)
        flow.start_s = now
        if flow.resource is not None:
            self._enqueue(flow.resource, _Pkt(flow.fid, 0, 0, 0.0, None), now)
            return
        if len(flow.route) < 2:      # self-send: no wire
            self._finish_flow(flow, now)
            return
        self._feed_source(flow, now)

    def run(self) -> float:
        """Process every pending event; returns the frontier time."""
        while True:
            self._drain()
            if not self._unstick():
                return self._frontier

    def run_until(self, t: float) -> float:
        """Process every event up to and including time ``t``, then stop
        with later events still pending — the checkpoint a mid-flight
        re-striping PUT uses to inspect its unsent remainder.  A later
        ``run()``/``run_until`` picks up exactly where this left off, in
        the same heap order a straight ``run()`` would have used; credit-
        deadlock recovery (``_unstick``) only engages on a full ``run``,
        so a partial drain is always conservative."""
        while self._heap and self._heap[0][0] <= t:
            et, _, kind, arg = heapq.heappop(self._heap)
            self._frontier = max(self._frontier, et)
            self._dispatch(et, kind, arg)
        self._frontier = max(self._frontier, t)
        return self._frontier

    def _drain(self) -> None:
        while self._heap:
            t, _, kind, arg = heapq.heappop(self._heap)
            self._frontier = max(self._frontier, t)
            self._dispatch(t, kind, arg)

    def _dispatch(self, t: float, kind: str, arg) -> None:
        if kind == "start":
            self._start_flow(self._flows[arg], t)
        elif kind == "retry":
            link = self._link(arg)
            if link.retry_at is not None and link.retry_at <= t:
                link.retry_at = None
            else:
                # a superseded ghost drained out of the heap on its own
                self._stale = max(0, self._stale - 1)
            self._try_start(arg, t)
        elif kind == "enqueue":
            key, pkt = arg
            self._enqueue(key, pkt, t)
        elif kind == "done":
            self._finish_flow(self._flows[arg.fid], t)
        elif kind == "arrive":
            pkt: _Pkt = arg
            flow = self._flows[pkt.fid]
            here = pkt.hop + 1
            up_key = self._link_key(pkt.route[pkt.hop],
                                    pkt.route[here], flow.channel)
            if here == len(pkt.route) - 1:
                # consumed at the endpoint: buffer drains immediately
                up = self._link(up_key)
                up.credits[flow.cidx] += pkt.nbytes
                self._try_start(up_key, t)
                self._j_flow(flow)
                flow.arrived += 1
                if flow.arrived == flow.npkts:
                    self._finish_flow(flow, t + flow.dst_over)
            else:
                nxt = self._link_key(pkt.route[here],
                                     pkt.route[here + 1], flow.channel)
                self._j_pkt(pkt)
                pkt.hop = here
                pkt.prev = up_key
                self._enqueue(nxt, pkt, t)

    def _unstick(self) -> bool:
        """Credit-deadlock recovery (escape credit); True if it made
        progress.

        Dimension-ordered routes on the wrap-around rings of a torus can
        form a cyclic buffer wait under partitioned per-class credits:
        every backlogged channel's head packet needs more credit than its
        link holds, and that credit can only return once a downstream
        link in the same cycle transmits.  The event heap then drains
        with packets still queued — a state a completing run can never
        reach (a startable head always has a pending wake event), so
        engaging here never perturbs a workload that finishes on its
        own.  Recovery mirrors hardware escape/bubble flow control: the
        oldest blocked head packet borrows exactly the missing credit —
        the class balance goes negative and is repaid by the packet's
        normal downstream credit return — guaranteeing at least one
        transmission of forward progress per call."""
        best = None
        for key, link in self._links.items():
            for c, q in enumerate(link.queues):
                if not q:
                    continue
                pkt = q[0]
                if pkt.nbytes <= link.credits[c] \
                        or self._flows[pkt.fid].resource is not None:
                    continue
                order = (pkt.fid, pkt.idx, pkt.hop)
                if best is None or order < best[0]:
                    best = (order, key, c)
        if best is None:
            return False
        _, key, c = best
        link = self._link(key)
        need = link.queues[c][0].nbytes - link.credits[c]
        link.credits[c] += need          # loan the escape credit
        self.deadlock_breaks += 1
        if self.telemetry is not None and self._journal is None:
            self.telemetry.on_escape_loan(key, c, need)
        self._try_start(key, self._frontier)
        link.credits[c] -= need          # balance now negative: the loan
        return True                      # is repaid on the credit return

    # -- results --------------------------------------------------------------
    def finish_s(self, fid: int) -> float:
        flow = self._flows[fid]
        if flow.finish_s is None:
            self.run()
        if flow.finish_s is None:
            raise RuntimeError(f"flow {fid} never completed "
                               "(unsatisfied dependency?)")
        return flow.finish_s

    def flow(self, fid: int) -> FlowResult:
        f = self._flows[fid]
        return FlowResult(
            fid=fid,
            src=f.route[0] if f.route else -1,
            dst=f.route[-1] if f.route else -1,
            nbytes=f.nbytes, hops=max(len(f.route) - 1, 0),
            start_s=f.start_s if f.start_s is not None else f.req_start,
            finish_s=self.finish_s(fid), label=f.label, cls=f.cls)

    def link_stats(self) -> dict:
        """Per-directed-link busy seconds and carried bytes (reporting);
        ``class_bytes`` breaks the carried bytes down by traffic-class
        TAG — always ``len(TrafficClass)`` entries, meaningful even under
        ``single_class`` arbitration (where all tags share one channel)."""
        return {k: {"busy_s": v.busy_s, "bytes": v.bytes_carried,
                    "class_bytes": tuple(v.class_bytes)}
                for k, v in ordered_link_items(self._links.items())}

    def class_stats(self, since: dict | None = None
                    ) -> dict[TrafficClass, float]:
        """Bytes carried per traffic-class tag, summed over every directed
        link (each wire hop counts — a 3-hop flow carries 3x its payload).
        Accounting is by the flow's ``cls`` tag, so the breakdown is
        meaningful even under ``single_class`` arbitration.

        ``since`` takes a previous ``class_stats()`` mapping and returns
        the per-class DELTA — the bytes carried inside one replay window,
        which is what the closed-loop QoS controller steers on (run-
        lifetime averages wash out exactly the transient it must react
        to).  Reading stats never mutates the sim, so two identical
        windows report identical deltas."""
        totals = [0.0] * len(TrafficClass)
        for link in self._links.values():
            for c in range(len(TrafficClass)):
                totals[c] += link.class_bytes[c]
        out = {cls: totals[int(cls)] for cls in TrafficClass}
        if since is not None:
            for cls in out:
                out[cls] -= float(since.get(cls, 0.0))
        return out

    # -- live QoS retune -------------------------------------------------------
    def set_qos(self, policy: QosPolicy) -> None:
        """Swap the arbitration policy on a LIVE timeline — the closed-loop
        controller's actuator.  Weights take effect at the next arbitration
        decision (the arbiter reads them per service); credit partitions are
        re-applied as a per-class DELTA to every existing link's balance, so
        outstanding in-flight debits (and any escape-credit loans) stay
        consistent: a link that owes 12 KB of BULK credit still owes it
        after the retune, it just owes it against the new partition."""
        if self._journal is not None:
            raise RuntimeError("set_qos under an active probe journal")
        if policy.n_classes != self.qos.n_classes:
            raise ValueError(
                "cannot change the virtual-channel count of a live sim "
                f"({self.qos.n_classes} -> {policy.n_classes})")
        old = self._class_credits
        new = policy.partition_credits(self.credit_bytes)
        self.qos = policy
        self._weights = policy.weight_vector()
        self._class_credits = new
        for key, link in self._links.items():
            for c in range(len(new)):
                if new[c] != old[c]:
                    link.credits[c] += new[c] - old[c]
        # a credit raise may unblock queued heads immediately
        for key, link in self._links.items():
            if any(link.queues):
                self._try_start(key, self._frontier)
        if self.telemetry is not None:
            self.telemetry.add("fabric.qos_retunes")

    # -- mid-flight re-striping ------------------------------------------------
    def unsent_bytes(self, fid: int) -> float:
        """Bytes of ``fid`` not yet committed to a route — the remainder a
        mid-flight re-stripe may re-split.  Packets already FED to the
        first link (queued or in flight) are committed: their per-packet
        route tags pin them to the path they launched on."""
        f = self._flows[fid]
        if f.finish_s is not None or f.resource is not None:
            return 0.0
        if f.start_s is None:
            return f.nbytes
        return max(f.nbytes - f.sent * f.pkt_bytes, 0.0)

    def restripe(self, fid: int, plan: Sequence[tuple]) -> list[int]:
        """Re-split flow ``fid``'s unsent remainder across a fresh
        ``striped_routes``-style plan ``[(route, frac), ...]`` — the
        mid-flight re-striping a half-sent bulk PUT performs when probed
        congestion has shifted since its original stripe plan.

        The flow itself is re-pointed at ``plan[0]`` and shrunk to carry
        that route's share of the remainder (its in-flight packets keep
        their per-packet route tags); every other plan route gets a fresh
        sibling flow starting now.  Returns the flow ids carrying the
        payload from here on (``fid`` first).  Each sibling re-issues a
        source descriptor, so it pays ``t_inject`` again — re-striping is
        not free, which is exactly why the controller only triggers it on
        a detected congestion shift."""
        if self._journal is not None:
            raise RuntimeError("restripe under an active probe journal")
        f = self._flows[fid]
        if f.resource is not None:
            raise ValueError("cannot restripe a resource occupancy")
        if f.start_s is None:
            raise ValueError(f"flow {fid} has not started; nothing is "
                             "committed yet — re-plan the whole transfer")
        rem = self.unsent_bytes(fid)
        routes: list[tuple[int, ...]] = []
        fracs: list[float] = []
        for route, frac in plan:
            route = tuple(route)
            if route[0] != f.route[0] or route[-1] != f.route[-1]:
                raise ValueError(f"plan route {route} does not join "
                                 f"{f.route[0]}->{f.route[-1]}")
            if frac > 0.0:
                routes.append(route)
                fracs.append(float(frac))
        if rem <= 0.0 or not routes:
            return [fid]
        total = sum(fracs)
        shares = [rem * fr / total for fr in fracs]
        # the original flow keeps plan[0]'s share; packets it already fed
        # were all full-size (the short tail packet is by construction the
        # LAST one, and rem > 0 means it has not been fed)
        sent_bytes = f.sent * f.pkt_bytes
        f.route = routes[0]
        f.nbytes = sent_bytes + shares[0]
        f.npkts = f.sent + int(-(-shares[0] // f.pkt_bytes))
        out = [fid]
        for route, share in zip(routes[1:], shares[1:]):
            nfid = self.inject(
                route[0], route[-1], share, start_s=self._frontier,
                route=route, channel=f.channel, cls=f.cls,
                label=(f.label + "+restripe") if f.label else "restripe")
            nf = self._flows[nfid]
            nf.src_over = f.src_over       # same endpoint overheads as the
            nf.dst_over = f.dst_over       # leg it split from (GPU touch,
            nf.pace_s = f.pace_s           # outbound read pacing)
            out.append(nfid)
        if self.telemetry is not None:
            self.telemetry.add("fabric.restripes")
            self.telemetry.add("fabric.restripe_siblings",
                               float(len(out) - 1))
        return out

    def prune(self) -> int:
        """Drop finished flows from the registry; returns how many.

        A long-lived timeline (the serving cluster's) accumulates settled
        flows forever otherwise, growing both the resident sim and every
        ``probe_route`` snapshot without bound.  The owner calls this
        once its window accounting has read the finishes it needs —
        pruned flow ids can no longer be queried or used as ``after=``
        dependencies.  Link state (busy-until, credits, queues) is live
        scheduling state and is kept."""
        done = [fid for fid, f in self._flows.items()
                if f.finish_s is not None]
        for fid in done:
            del self._flows[fid]
        return len(done)

    # -- what-if probing -------------------------------------------------------
    def _snapshot(self) -> tuple:
        """Record every piece of mutable scheduling state — links (queues,
        credits, arbiter clocks), flows' progress, packets in flight, the
        event heap and the counters — WITHOUT copying the static half of
        the sim (torus, net, fault map, policy).  Bounded by the in-flight
        state, where the old ``copy.deepcopy`` ghost was O(whole sim) per
        probe."""
        pkts: list[tuple] = []
        seen: set[int] = set()

        def note(p: _Pkt) -> None:
            if id(p) not in seen:
                seen.add(id(p))
                pkts.append((p, p.hop, p.prev))

        links = {}
        for k, link in self._links.items():
            queues = tuple(list(q) for q in link.queues)
            for q in queues:
                for p in q:
                    note(p)
            links[k] = (link.free_at, queues, list(link.credits),
                        list(link.vtime), link.vfloor, link.busy_s,
                        link.bytes_carried, list(link.class_bytes),
                        link.retry_at)
        heap = list(self._heap)
        for _, _, kind, arg in heap:
            if kind in ("arrive", "done"):
                note(arg)
            elif kind == "enqueue":
                note(arg[1])
        flows = {fid: (f.sent, f.arrived, f.req_start, f.start_s,
                       f.finish_s, f.pending, list(f.dependents))
                 for fid, f in self._flows.items()}
        return (links, pkts, heap, flows, self._frontier,
                self._seq_n, self._fid_n, self._stale)

    def _restore(self, snap: tuple) -> None:
        """Put every mutable field back exactly as ``_snapshot`` saw it;
        objects created since (ghost flows, their packets and events, new
        links) are dropped.  The snapshot is consumed — its saved lists
        become the live state."""
        links, pkts, heap, flows, frontier, seq_n, fid_n, stale = snap
        for k in [k for k in self._links if k not in links]:
            del self._links[k]
        for k, (free_at, queues, credits, vtime, vfloor, busy_s,
                carried, class_bytes, retry_at) in links.items():
            link = self._links[k]
            link.free_at = free_at
            link.queues = queues
            link.credits = credits
            link.vtime = vtime
            link.vfloor = vfloor
            link.busy_s = busy_s
            link.bytes_carried = carried
            link.class_bytes = class_bytes
            link.retry_at = retry_at
        for p, hop, prev in pkts:
            p.hop = hop
            p.prev = prev
        self._heap = heap
        for fid in [fid for fid in self._flows if fid not in flows]:
            del self._flows[fid]
        for fid, (sent, arrived, req_start, start_s, finish_s, pending,
                  dependents) in flows.items():
            f = self._flows[fid]
            f.sent = sent
            f.arrived = arrived
            f.req_start = req_start
            f.start_s = start_s
            f.finish_s = finish_s
            f.pending = pending
            f.dependents = dependents
        self._frontier = frontier
        self._seq_n = seq_n
        self._fid_n = fid_n
        self._stale = stale

    def _rollback(self, j: _Journal) -> None:
        """Undo everything the probe touched, exactly as the journal's
        pre-images recorded it; ghost flows/links/events vanish."""
        for key, saved in j.links.items():
            if saved is None:
                self._links.pop(key, None)     # link created by the probe
                continue
            link = self._links[key]
            (link.free_at, link.queues, link.credits, link.vtime,
             link.vfloor, link.busy_s, link.bytes_carried,
             link.class_bytes, link.retry_at) = saved
        for fid in range(j.fid_n, self._fid_n):   # ghost flows
            self._flows.pop(fid, None)
        for fid, (sent, arrived, req_start, start_s, finish_s, pending,
                  dependents) in j.flows.items():
            f = self._flows[fid]
            f.sent = sent
            f.arrived = arrived
            f.req_start = req_start
            f.start_s = start_s
            f.finish_s = finish_s
            f.pending = pending
            f.dependents = dependents
        for p, hop, prev in j.pkts.values():
            p.hop = hop
            p.prev = prev
        self._heap = j.heap
        self._frontier = j.frontier
        self._seq_n = j.seq_n
        self._fid_n = j.fid_n
        self._stale = j.stale

    def probe_route(self, route: Sequence[int], nbytes: float, *,
                    start_s: float | None = None, **kw) -> float:
        """Simulated completion time of a hypothetical flow along
        ``route`` against the CURRENT traffic, without committing anything
        to the timeline.

        Runs on the live simulator under a copy-on-write journal: state is
        recorded lazily the first time the ghost traffic touches it, so
        the rollback cost is bounded by the links on the probed route plus
        the flows crossing them — not the whole resident sim.  The last
        probe's touch counts are published in ``last_probe_report``."""
        start = self._frontier if start_s is None else start_s

        def ghost() -> float:
            fid = self.inject(route[0], route[-1], nbytes, start_s=start,
                              route=route, **kw)
            return self.finish_s(fid) - start

        db = self.deadlock_breaks
        if self._journal is not None:
            # nested probe: fall back to the eager full snapshot
            snap = self._snapshot()
            try:
                return ghost()
            finally:
                self._restore(snap)
                self.deadlock_breaks = db
        j = _Journal(heap=list(self._heap), frontier=self._frontier,
                     seq_n=self._seq_n, fid_n=self._fid_n,
                     stale=self._stale)
        self._journal = j
        try:
            out = ghost()
        finally:
            self._journal = None
            self._rollback(j)
            self.deadlock_breaks = db
        self.last_probe_report = {
            "links_touched": len(j.links),
            "flows_touched": len(j.flows),
            "pkts_touched": len(j.pkts),
            "links_total": len(self._links),
            "flows_total": len(self._flows),
        }
        if self.telemetry is not None:
            # stamped AFTER rollback, once per top-level probe (nested
            # probes are fully suppressed under the outer journal) —
            # the ONE counter a probe moves, by design; everything else
            # must match a never-probed control bitwise
            self.telemetry.add("fabric.probes")
        return out


# ----------------------------------------------------------------------------
# schedule traffic: CollectiveSchedule -> flows
# ----------------------------------------------------------------------------

def _transfer_endpoints(torus: Torus, schedule: CollectiveSchedule,
                        phase: Phase, tr: Transfer):
    """(src_rank, dst_rank, route|None) triples for one transfer —
    every lane of the phase axis carries the ppermute's messages."""
    if phase.kind == P2P:
        yield phase.ring[0], phase.ring[-1], phase.ring
        return
    dim = schedule.axis_dims[schedule.axes.index(phase.axis)]
    dead = schedule.faults.dead_nodes
    for lane in _lanes(torus, dim):
        for a, b in tr.perm:
            ca = tuple(a if c is None else c for c in lane)
            cb = tuple(b if c is None else c for c in lane)
            ra, rb = torus.rank(ca), torus.rank(cb)
            if ra in dead or rb in dead:
                continue
            yield ra, rb, None


def inject_schedule(sim: FabricSim, schedule: CollectiveSchedule,
                    nbytes: float, *, start_s: float | None = None,
                    after: Sequence[int] = (),
                    granularity: str = "phase",
                    cls: TrafficClass = TrafficClass.COLLECTIVE,
                    **endpoint_kw) -> list[int]:
    """Inject a collective's traffic into a (shared) sim; returns the
    tail flow ids (the collective is done when all of them finish).

    ``granularity="round"`` barriers every wall-clock round on the
    previous one — the analytic model's sequential-rounds rule, used by
    the ``backend="sim"`` estimator.  ``granularity="phase"`` aggregates
    each phase's rounds into one flow per (lane, direction) — per-link
    bytes identical, round barriers elided — the cheap form the serving
    timeline uses for background traffic.  ``cls`` tags every flow of the
    collective (serving decode steps pass ``TrafficClass.DECODE``).
    """
    if granularity not in ("round", "phase"):
        raise ValueError(f"unknown granularity {granularity!r}")
    tail = list(after)
    for ph in schedule.phases:
        if not ph.steps:
            continue
        if granularity == "phase":
            fids = []
            rounds = len(ph.steps)
            for ti, tr in enumerate(ph.steps[0].transfers):
                for ra, rb, route in _transfer_endpoints(
                        sim.torus, schedule, ph, tr):
                    fids.append(sim.inject(
                        ra, rb, tr.frac * nbytes * rounds, start_s=start_s,
                        route=route, after=tuple(tail), channel=ti,
                        cls=cls, **endpoint_kw))
            if fids:
                tail = fids
        else:
            for st in ph.steps:
                fids = []
                for ti, tr in enumerate(st.transfers):
                    for ra, rb, route in _transfer_endpoints(
                            sim.torus, schedule, ph, tr):
                        fids.append(sim.inject(
                            ra, rb, tr.frac * nbytes, start_s=start_s,
                            route=route, after=tuple(tail), channel=ti,
                            cls=cls, **endpoint_kw))
                if fids:
                    tail = fids
    return tail


def simulate_schedule(schedule: CollectiveSchedule, nbytes: int,
                      net: NetModel | None = None, *,
                      cls: TrafficClass = TrafficClass.COLLECTIVE,
                      qos: QosPolicy | None = None,
                      fidelity: str = "packet",
                      **endpoint_kw) -> CostEstimate:
    """Event-driven price of one collective on a quiet fabric — the
    ``backend="sim"`` path of ``fabric.estimate``.

    Rounds barrier on each other exactly like the analytic model's
    sequential steps, so on single-flow schedules (no two messages of a
    round sharing a link direction) the two backends must agree — the
    differential in ``tests/fabric_checks.py`` holds both to it.  The
    default (no ``qos``) prices on the single-class FIFO link.
    ``fidelity`` selects the simulator tier (``fluid.make_sim``): the
    default ``"packet"`` oracle, or the ``"fluid"``/``"hybrid"`` fast
    path for large tori.
    """
    if fidelity == "packet":
        sim: FabricSim = FabricSim(Torus(schedule.torus_dims), net,
                                   faults=schedule.faults, qos=qos)
    else:
        from repro.core.fabric.fluid import make_sim
        sim = make_sim(Torus(schedule.torus_dims), net, fidelity=fidelity,
                       faults=schedule.faults, qos=qos)
    phase_s = []
    t = 0.0
    tail: list[int] = []
    for ph in schedule.phases:
        sub = dataclasses.replace(schedule, phases=(ph,))
        new_tail = inject_schedule(sim, sub, nbytes, start_s=t,
                                   after=tuple(tail), granularity="round",
                                   cls=cls, **endpoint_kw)
        if new_tail != list(tail):
            tail = new_tail
            sim.run()
            end = max(sim.finish_s(f) for f in tail)
        else:
            end = t
        phase_s.append(max(end - t, 0.0))
        t = end
    return CostEstimate(total_s=t, phase_s=tuple(phase_s),
                        rounds=schedule.rounds,
                        bytes_per_rank=schedule.bytes_per_rank(nbytes),
                        max_hops=schedule.max_hops)


# ----------------------------------------------------------------------------
# congestion-aware route selection (fault.py's BFS machinery, probed by
# simulated completion time)
# ----------------------------------------------------------------------------

def candidate_routes(torus: Torus, src: int, dst: int,
                     faults: FaultMap | None = None) -> list[tuple[int, ...]]:
    """Loop-free candidate routes src -> dst over the surviving fabric:
    the dimension-ordered minimal path plus, per live first hop, the BFS
    shortest path that commits to that first link (the detour family the
    router could select).  Sorted by hop count; raises ``UnroutableError``
    when no route survives.

    Cached per (torus dims, src, dst, fault map): within one fault epoch,
    repeated probes and re-striping pay the BFS detour family exactly
    once (``clear_route_cache`` drops dead epochs)."""
    faults = faults or FaultMap()
    key = (torus.dims, src, dst, faults)
    hit = _candidates_cache.get(key, _MISS)
    if hit is _MISS:
        ROUTE_CACHE_STATS["cand_misses"] += 1
        if len(_candidates_cache) >= _ROUTE_CACHE_CAP:
            _candidates_cache.clear()
        hit = _candidates_cache[key] = _candidate_routes_uncached(
            torus, src, dst, faults)
    else:
        ROUTE_CACHE_STATS["cand_hits"] += 1
    return list(hit)


def _candidate_routes_uncached(torus: Torus, src: int, dst: int,
                               faults: FaultMap) -> list[tuple[int, ...]]:
    for r in (src, dst):
        if r in faults.dead_nodes:
            raise UnroutableError(f"route endpoint rank {r} is dead")
    if src == dst:
        return [(src,)]
    routes: list[tuple[int, ...]] = []
    if not faults:
        routes.append(tuple(torus.route(src, dst)))
    src_blocked = FaultMap(faults.dead_nodes | {src}, faults.dead_links)
    for n in torus.neighbors(src):
        if not faults.link_ok(src, n):
            continue
        if n == dst:
            path: list[int] | None = [n]
        else:
            path = _cached_bfs(torus, n, dst, src_blocked)
        if path is None:
            continue
        routes.append((src, *path))
    seen: set[tuple[int, ...]] = set()
    out = [r for r in routes if not (r in seen or seen.add(r))]
    if not out:
        raise UnroutableError(
            f"no surviving route {src} -> {dst}: the fault map "
            "partitions the fabric")
    return sorted(out, key=len)


def best_route(sim: FabricSim, src: int, dst: int, nbytes: float, *,
               faults: FaultMap | None = None,
               start_s: float | None = None,
               cls: TrafficClass = TrafficClass.BULK
               ) -> tuple[tuple[int, ...], float]:
    """The candidate route with the least *simulated* completion time
    against the sim's current traffic (ties break toward fewer hops —
    candidates come sorted, and ``min`` is stable)."""
    cands = candidate_routes(sim.torus, src, dst, faults)
    timed = [(sim.probe_route(r, nbytes, start_s=start_s, cls=cls), len(r), r)
             for r in cands]
    t, _, route = min(timed, key=lambda x: (x[0], x[1]))
    return route, t


def striped_routes(sim: FabricSim, src: int, dst: int, nbytes: float, *,
                   k: int = 3, faults: FaultMap | None = None,
                   start_s: float | None = None,
                   cls: TrafficClass = TrafficClass.BULK
                   ) -> list[tuple[tuple[int, ...], float]]:
    """Multi-path stripe plan for one bulk transfer: the ``k`` candidate
    routes with the least probed completion time, each with the fraction
    of the payload it should carry — proportional to its probed goodput
    (``nbytes / probed_s``), so a congested member of the stripe set gets
    proportionally less and the stripes finish together.

    Returns ``[(route, frac), ...]`` with fracs summing to 1; degenerates
    to ``[(best_route, 1.0)]`` when only one candidate survives.  This is
    the ROADMAP "adaptive multi-path routing" item: one transfer split
    across several loop-free detour-family routes at once."""
    if k < 1:
        raise ValueError(f"stripe count k must be >= 1, got {k}")
    cands = candidate_routes(sim.torus, src, dst, faults)
    timed = sorted(
        ((sim.probe_route(r, nbytes, start_s=start_s, cls=cls), len(r), r)
         for r in cands), key=lambda x: (x[0], x[1]))
    picked = timed[:k]
    goodput = [1.0 / max(t, 1e-12) for t, _, _ in picked]
    total = sum(goodput)
    return [(r, g / total) for (_, _, r), g in zip(picked, goodput)]


def stripe_counts(plan: Sequence[tuple[tuple[int, ...], float]],
                  n_items: int) -> list[int]:
    """Apportion ``n_items`` indivisible units (pages) across a
    ``striped_routes`` plan: largest-remainder rounding of the per-route
    fractions, so the counts always sum to ``n_items`` exactly.  Entries
    may be 0 when ``n_items < len(plan)`` — callers drop those stripes.
    The ONE page-split rule shared by the serving cluster, the QoS
    benchmark and the tests, so the gated numbers price exactly the
    production split."""
    if n_items < 0:
        raise ValueError(f"negative n_items {n_items}")
    exact = [frac * n_items for _, frac in plan]
    counts = [int(e) for e in exact]
    short = n_items - sum(counts)
    order = sorted(range(len(plan)), key=lambda i: exact[i] - counts[i],
                   reverse=True)
    for i in order[:short]:
        counts[i] += 1
    return counts
