"""Lowering: collective + ``Torus`` + axis spec -> ``CollectiveSchedule``.

This is the single place in the repo where ring orderings, chunk fractions
and physical hop counts are derived.  The executor, the cost estimator and
the fault rewriter all consume the schedules produced here; none of them
re-derives hop math.

Lowering is fault-aware: given a ``FaultMap`` it

  * drops dead axis positions from every ring ("shrunk rings" — a position
    is dead when any rank in its hyperplane is dead, exact for 1D meshes
    and conservative for wider ones, since one ppermute perm is shared by
    every lane of the axis);
  * prices each surviving (src, dst) pair by BFS over the surviving fabric
    graph, so a transfer whose direct link died carries ``hops > 1`` — the
    dimension-ordered router's detour around the failure.
"""
from __future__ import annotations

import itertools
from collections import deque
from typing import Sequence

from repro.core.fabric.schedule import (
    A2A, AG, AR, HALO, P2P, RS, Bucket, BucketPlan, CollectiveSchedule,
    FaultMap, Phase, Step, Transfer)
from repro.core.topology import Torus


class UnroutableError(RuntimeError):
    """The fault map partitions the fabric: no detour exists."""


# ----------------------------------------------------------------------------
# fabric graph helpers (the only hop math in the repo)
# ----------------------------------------------------------------------------

def _bfs_path(torus: Torus, src: int, dst: int,
              faults: FaultMap) -> list[int] | None:
    """Shortest surviving rank path src -> dst inclusive, else None — the
    ONE fault-aware BFS (collective detour pricing and p2p routing both
    ride it, so their views of the surviving graph can never diverge)."""
    if src == dst:
        return [src]
    prev = {src: src}
    frontier = deque([src])
    while frontier:
        r = frontier.popleft()
        for n in torus.neighbors(r):
            if n in prev or not faults.link_ok(r, n):
                continue
            prev[n] = r
            if n == dst:
                path = [dst]
                while path[-1] != src:
                    path.append(prev[path[-1]])
                return path[::-1]
            frontier.append(n)
    return None


def _bfs_hops(torus: Torus, src: int, dst: int, faults: FaultMap) -> int | None:
    """Shortest surviving-path length between two live ranks, else None."""
    path = _bfs_path(torus, src, dst, faults)
    return None if path is None else len(path) - 1


def _lanes(torus: Torus, dim: int):
    """All coordinate assignments of the dims orthogonal to ``dim``."""
    ranges = [range(torus.dims[i]) if i != dim else (None,)
              for i in range(torus.ndims)]
    return itertools.product(*ranges)


def _pair_hops(torus: Torus, dim: int, a: int, b: int,
               faults: FaultMap) -> int:
    """Physical hops for an axis-position pair a -> b, worst lane wins."""
    n = torus.dims[dim]
    if not faults:
        delta = abs(a - b)
        return max(1, min(delta, n - delta))
    worst = 0
    routable_lane = False
    for lane in _lanes(torus, dim):
        ca = tuple(a if c is None else c for c in lane)
        cb = tuple(b if c is None else c for c in lane)
        ra, rb = torus.rank(ca), torus.rank(cb)
        if ra in faults.dead_nodes or rb in faults.dead_nodes:
            continue  # a dead endpoint's lane carries no live payload
        hops = _bfs_hops(torus, ra, rb, faults)
        if hops is None:
            raise UnroutableError(
                f"no surviving route {ca} -> {cb} (dim {dim})")
        routable_lane = True
        worst = max(worst, hops)
    if not routable_lane:
        raise UnroutableError(
            f"every lane of axis positions {a} -> {b} (dim {dim}) is dead")
    return max(1, worst)


def live_ring(torus: Torus, dim: int, faults: FaultMap) -> tuple[int, ...]:
    """Participating axis positions in cyclic order (shrunk under faults)."""
    dead = {torus.coords(r)[dim] for r in faults.dead_nodes}
    ring = tuple(p for p in range(torus.dims[dim]) if p not in dead)
    if not ring:
        raise UnroutableError(f"all positions of dim {dim} are dead")
    return ring


def axis_fault_penalty(torus: Torus, dim: int,
                       faults: FaultMap) -> tuple[int, int]:
    """(max detour hops, dead positions) for one axis — the fault rewriter's
    axis-ordering key."""
    ring = live_ring(torus, dim, faults)
    m = len(ring)
    if m <= 1:
        return (0, torus.dims[dim] - m)
    worst = max(_pair_hops(torus, dim, ring[i], ring[(i + 1) % m], faults)
                for i in range(m))
    return (worst, torus.dims[dim] - m)


# ----------------------------------------------------------------------------
# phase construction
# ----------------------------------------------------------------------------

def _dir_transfer(torus: Torus, dim: int, ring: tuple[int, ...], sgn: int,
                  frac: float, faults: FaultMap, combine: str) -> Transfer:
    m = len(ring)
    perm = tuple((ring[i], ring[(i + sgn) % m]) for i in range(m))
    hops = max(_pair_hops(torus, dim, s, d, faults) for s, d in perm)
    return Transfer(perm=perm, frac=frac, hops=hops, combine=combine)


def _ring_phase(kind: str, torus: Torus, axis: str, dim: int, *,
                scale: float, bidirectional: bool, faults: FaultMap,
                frac_per_dir: float, combine: str,
                mean: bool = False) -> Phase:
    ring = live_ring(torus, dim, faults)
    m = len(ring)
    if m <= 1:
        return Phase(kind, axis, ring, steps=(), scale=scale, mean=mean)
    sgns = (+1, -1) if bidirectional else (+1,)
    transfers = tuple(_dir_transfer(torus, dim, ring, sgn, frac_per_dir,
                                    faults, combine) for sgn in sgns)
    steps = tuple(Step(transfers) for _ in range(m - 1))
    return Phase(kind, axis, ring, steps, scale=scale, mean=mean)


def _entries(torus: Torus, axes: Sequence[str],
             axis_dims: Sequence[int] | None) -> list[tuple[str, int]]:
    axes = tuple(axes)
    dims = tuple(axis_dims) if axis_dims is not None else tuple(
        range(len(axes)))
    if len(axes) != len(dims):
        raise ValueError("axes/axis_dims arity mismatch")
    if not axes:
        raise ValueError("need at least one axis")
    for d in dims:
        if not 0 <= d < torus.ndims:
            raise ValueError(f"axis dim {d} out of range for {torus.dims}")
    if len(set(dims)) != len(dims):
        raise ValueError(f"repeated torus dims {dims}")
    return list(zip(axes, dims))


# ----------------------------------------------------------------------------
# public lowerings
# ----------------------------------------------------------------------------

def lower_reduce_scatter(torus: Torus, axes: Sequence[str], *,
                         axis_dims: Sequence[int] | None = None,
                         bidirectional: bool = True, mean: bool = False,
                         faults: FaultMap | None = None) -> CollectiveSchedule:
    """Dimension-ordered reduce-scatter: one ring pass per axis, the working
    set shrinking by the (live) ring size at every phase."""
    faults = faults or FaultMap()
    entries = _entries(torus, axes, axis_dims)
    phases, scale = [], 1.0
    for name, dim in entries:
        m = len(live_ring(torus, dim, faults))
        ndir = 2 if (bidirectional and m > 1) else 1
        ph = _ring_phase(RS, torus, name, dim, scale=scale,
                         bidirectional=bidirectional, faults=faults,
                         frac_per_dir=scale / (max(m, 1) * ndir),
                         combine="sum", mean=mean)
        phases.append(ph)
        scale /= max(m, 1)
    return CollectiveSchedule(RS, tuple(a for a, _ in entries),
                              tuple(d for _, d in entries), torus.dims,
                              tuple(phases), faults, bidirectional, mean)


def lower_all_gather(torus: Torus, axes: Sequence[str], *,
                     axis_dims: Sequence[int] | None = None,
                     bidirectional: bool = True,
                     faults: FaultMap | None = None) -> CollectiveSchedule:
    """All-gather, walking ``axes`` in the given order (callers inverting a
    reduce-scatter pass the reversed axis list); fractions are relative to
    the *input chunk* at each rank, which grows by the ring size per phase."""
    faults = faults or FaultMap()
    entries = _entries(torus, axes, axis_dims)
    phases, scale = [], 1.0
    for name, dim in entries:
        m = len(live_ring(torus, dim, faults))
        ndir = 2 if (bidirectional and m > 1) else 1
        ph = _ring_phase(AG, torus, name, dim, scale=scale,
                         bidirectional=bidirectional, faults=faults,
                         frac_per_dir=scale / ndir, combine="write")
        phases.append(ph)
        scale *= max(m, 1)
    return CollectiveSchedule(AG, tuple(a for a, _ in entries),
                              tuple(d for _, d in entries), torus.dims,
                              tuple(phases), faults, bidirectional, False)


def lower_all_reduce(torus: Torus, axes: Sequence[str], *,
                     axis_dims: Sequence[int] | None = None,
                     bidirectional: bool = True, mean: bool = False,
                     faults: FaultMap | None = None) -> CollectiveSchedule:
    """The bytes-optimal torus all-reduce: reduce-scatter X,Y,..,Z then
    all-gather Z,..,Y,X — 2(Ni-1)/Ni of the live working set per axis, all
    of it first-neighbour traffic (APEnet+ dimension-ordered routing)."""
    faults = faults or FaultMap()
    entries = _entries(torus, axes, axis_dims)
    phases, scale = [], 1.0
    for name, dim in entries:
        m = len(live_ring(torus, dim, faults))
        ndir = 2 if (bidirectional and m > 1) else 1
        phases.append(_ring_phase(
            RS, torus, name, dim, scale=scale, bidirectional=bidirectional,
            faults=faults, frac_per_dir=scale / (max(m, 1) * ndir),
            combine="sum", mean=mean))
        scale /= max(m, 1)
    for name, dim in reversed(entries):
        m = len(live_ring(torus, dim, faults))
        ndir = 2 if (bidirectional and m > 1) else 1
        phases.append(_ring_phase(
            AG, torus, name, dim, scale=scale, bidirectional=bidirectional,
            faults=faults, frac_per_dir=scale / ndir, combine="write"))
        scale *= max(m, 1)
    return CollectiveSchedule(AR, tuple(a for a, _ in entries),
                              tuple(d for _, d in entries), torus.dims,
                              tuple(phases), faults, bidirectional, mean)


def lower_all_to_all(torus: Torus, axis: str, *,
                     axis_dims: Sequence[int] | None = None,
                     faults: FaultMap | None = None) -> CollectiveSchedule:
    """Store-and-forward ring all-to-all: the full buffer circulates n-1
    hops, every rank peeling off its addressed row at each stop — how the
    torus router forwards non-local packets.  Node faults are unroutable
    (rows addressed to a dead rank have nowhere to land); link faults only
    raise the hop count."""
    faults = faults or FaultMap()
    [(name, dim)] = _entries(torus, (axis,), axis_dims)
    ring = live_ring(torus, dim, faults)
    n = torus.dims[dim]
    if len(ring) != n:
        raise UnroutableError(
            "all-to-all cannot shrink its ring: rows addressed to dead "
            f"positions {sorted(set(range(n)) - set(ring))} are undeliverable")
    if n == 1:
        steps: tuple[Step, ...] = ()
    else:
        tr = _dir_transfer(torus, dim, ring, +1, 1.0, faults, "shift")
        steps = tuple(Step((tr,)) for _ in range(n - 1))
    return CollectiveSchedule(
        A2A, (name,), (dim,), torus.dims,
        (Phase(A2A, name, ring, steps),), faults, False, False)


def lower_halo_exchange(torus: Torus, axis: str, *,
                        axis_dims: Sequence[int] | None = None,
                        faults: FaultMap | None = None) -> CollectiveSchedule:
    """One round, both directions: each rank puts its facing slab into both
    ring neighbours (a pair of one-sided RDMA puts).  Under faults the ring
    shrinks, so live ranks exchange halos with their nearest live
    neighbours at the detour's hop cost."""
    faults = faults or FaultMap()
    [(name, dim)] = _entries(torus, (axis,), axis_dims)
    ring = live_ring(torus, dim, faults)
    if len(ring) <= 1:
        phase = Phase(HALO, name, ring, steps=())
    else:
        transfers = tuple(_dir_transfer(torus, dim, ring, sgn, 1.0, faults,
                                        "write") for sgn in (+1, -1))
        phase = Phase(HALO, name, ring, (Step(transfers),))
    return CollectiveSchedule(HALO, (name,), (dim,), torus.dims, (phase,),
                              faults, True, False)


def lower_p2p(torus: Torus, src: int, dst: int, *,
              faults: FaultMap | None = None) -> CollectiveSchedule:
    """Point-to-point lowering: one multi-hop unicast as a schedule.

    An RDMA PUT from rank ``src`` to rank ``dst`` is a single fabric
    message forwarded hop-by-hop by the routers along the dimension-ordered
    (X then Y then Z) minimal path — the endpoints pay injection/reception
    once, every intermediate router adds ``t_hop`` (paper §1).  The
    schedule therefore carries ONE transfer whose ``hops`` is the route
    length; ``fabric.estimate`` prices it exactly like a collective's
    detour transfer.

    Unlike the axis-wise collectives, a unicast is a *global* route: the
    phase ``ring`` lists the fabric **ranks** visited in forwarding order
    (route annotation, not axis positions) and the transfer perm is the
    single (src, dst) rank pair.  ``fault.rewrite`` re-lowers from that
    annotation: under a ``FaultMap`` the route becomes the BFS shortest
    path over the surviving fabric — the dimension-ordered router's detour
    — and ``UnroutableError`` is raised when src/dst are separated (or an
    endpoint itself is dead).
    """
    faults = faults or FaultMap()
    for r in (src, dst):
        if not 0 <= r < torus.size:
            raise ValueError(f"rank {r} out of range for torus {torus.dims}")
        if r in faults.dead_nodes:
            raise UnroutableError(f"p2p endpoint rank {r} is dead")
    if not faults:
        route = torus.route(src, dst)
    else:
        path = _bfs_path(torus, src, dst, faults)
        if path is None:
            raise UnroutableError(
                f"no surviving route {src} -> {dst}: the fault map "
                "partitions the fabric")
        route = path
    return lower_route(torus, route, faults=faults)


def lower_route(torus: Torus, route: Sequence[int], *,
                faults: FaultMap | None = None) -> CollectiveSchedule:
    """Lower an *explicit* unicast route (ranks in forwarding order) to a
    P2P schedule — same shape ``lower_p2p`` produces, but the caller picks
    the path.  This is the congestion-aware router's entry point: the
    serving cluster probes ``fabric.sim.candidate_routes`` by simulated
    completion time and lowers the winner here.  Every consecutive pair
    must be a live first-neighbour link of the torus."""
    faults = faults or FaultMap()
    route = tuple(route)
    if not route:
        raise ValueError("empty route")
    for r in route:
        if not 0 <= r < torus.size:
            raise ValueError(f"rank {r} out of range for torus {torus.dims}")
    for a, b in zip(route, route[1:]):
        if b not in torus.neighbors(a):
            raise ValueError(f"route hop {a} -> {b} is not a torus link")
        if not faults.link_ok(a, b):
            raise UnroutableError(f"route hop {a} -> {b} is dead")
    src, dst = route[0], route[-1]
    hops = len(route) - 1
    if hops == 0:
        steps: tuple[Step, ...] = ()
    else:
        steps = (Step((Transfer(perm=((src, dst),), frac=1.0, hops=hops,
                                combine="write"),)),)
    phase = Phase(P2P, "route", route, steps)
    return CollectiveSchedule(P2P, ("route",), (0,), torus.dims, (phase,),
                              faults, False, False)


# ----------------------------------------------------------------------------
# gradient bucketing (the overlap engine's lowering)
# ----------------------------------------------------------------------------

def _leaf_sizes(tree_or_sizes) -> list[int]:
    import jax

    import math

    leaves = jax.tree.leaves(tree_or_sizes)
    sizes = []
    for leaf in leaves:
        if isinstance(leaf, (int, float)):
            sizes.append(int(leaf))
        elif hasattr(leaf, "shape"):
            sizes.append(int(math.prod(leaf.shape)))
        else:
            raise TypeError(f"cannot size bucket leaf {type(leaf)}")
    return sizes


def plan_buckets(tree_or_sizes, bucket_bytes: int, *, itemsize: int = 4,
                 reverse: bool = True) -> BucketPlan:
    """Lower a param tree (or flat leaf-size list) to a ``BucketPlan``.

    Greedy packing in gradient-readiness order: during backward the *last*
    parameters of the forward produce their gradients first, so leaves are
    walked in reverse tree order by default and a bucket closes as soon as
    it holds at least ``bucket_bytes`` of wire payload (``itemsize`` bytes
    per element — 4 for the fp32 gradient wire the apex trainer uses).
    One undersized trailing bucket absorbs the remainder.
    """
    if bucket_bytes <= 0:
        raise ValueError(f"bucket_bytes must be > 0, got {bucket_bytes}")
    if itemsize <= 0:
        raise ValueError(f"itemsize must be > 0, got {itemsize}")
    sizes = _leaf_sizes(tree_or_sizes)
    if not sizes:
        raise ValueError("empty param tree: nothing to bucket")
    order = range(len(sizes) - 1, -1, -1) if reverse else range(len(sizes))
    buckets: list[Bucket] = []
    cur: list[int] = []
    cur_bytes = 0
    for i in order:
        cur.append(i)
        cur_bytes += sizes[i] * itemsize
        if cur_bytes >= bucket_bytes:
            buckets.append(Bucket(len(buckets), tuple(cur), cur_bytes))
            cur, cur_bytes = [], 0
    if cur:
        buckets.append(Bucket(len(buckets), tuple(cur), cur_bytes))
    return BucketPlan(tuple(buckets), bucket_bytes, len(sizes))


_LOWERERS = {
    RS: lower_reduce_scatter,
    AG: lower_all_gather,
    AR: lower_all_reduce,
}


def lower(collective: str, torus: Torus, axes: Sequence[str],
          **kw) -> CollectiveSchedule:
    """Generic entry point; see the per-collective lowerings."""
    if collective in _LOWERERS:
        return _LOWERERS[collective](torus, axes, **kw)
    if collective in (A2A, HALO):
        axes = tuple(axes)
        if len(axes) != 1:
            raise ValueError(f"{collective} is single-axis, got {axes}")
        fn = lower_all_to_all if collective == A2A else lower_halo_exchange
        return fn(torus, axes[0], **kw)
    if collective == P2P:
        raise ValueError(
            "p2p is rank-addressed, not axis-addressed; "
            "use lower_p2p(torus, src, dst)")
    raise ValueError(f"unknown collective {collective!r}")
