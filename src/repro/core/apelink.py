"""APElink transmission control logic — paper §2.3, §3 (Fig 3) and §6.

Two artifacts live here:

1. A **bit-accurate word-stuffing framing codec** (the "light, low-level,
   word-stuffing protocol" of §2.3).  Packets are delimited by a MAGIC word;
   a payload word colliding with MAGIC is escaped by doubling it.  The codec
   is invertible (property-tested) and its measured overhead matches the
   analytic efficiency model below.

2. The **analytic efficiency / latency / bandwidth model** used to reproduce
   the paper's numbers: channel efficiency 0.784, ~2.2 GB/s observed link
   bandwidth, ~40 KB flow-control footprint per channel, and the Fig 3a/3b/3c
   latency & bandwidth curves (P2P vs host-staged vs InfiniBand+MVAPICH).
   The same model derates ICI bandwidth in the TPU roofline's collective term
   (see ``benchmarks/roofline.py``).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import hw

# ----------------------------------------------------------------------------
# Word-stuffing framing codec (32-bit words).
# ----------------------------------------------------------------------------

MAGIC = np.uint32(0x4150454E)  # "APEN"

# Packet wire format (4 framing words per packet, cf. the efficiency model):
#
#   MAGIC  hdr(dest,len)  <payload, MAGIC doubled>  MAGIC  crc
#
# The header carries the payload length, so the trailing MAGIC+crc is
# unambiguous; stuffing (doubling literal MAGIC words) exists so a receiver
# can re-synchronise on packet boundaries after corruption, exactly as in the
# APElink word-stuffing protocol.


def _crc(payload: np.ndarray) -> np.uint32:
    """Cheap XOR checksum standing in for the link CRC."""
    if payload.size == 0:
        return np.uint32(0)
    return np.uint32(np.bitwise_xor.reduce(payload))


def pack_header(dest: int, length: int) -> np.uint32:
    if not 0 <= dest < 256:
        raise ValueError("dest must fit 8 bits")
    if not 0 <= length < (1 << 24):
        raise ValueError("length must fit 24 bits")
    return np.uint32((dest << 24) | length)


def unpack_header(word: np.uint32) -> tuple[int, int]:
    w = int(word)
    return (w >> 24) & 0xFF, w & 0xFFFFFF


def encode_packet(payload: np.ndarray, dest: int = 0) -> np.ndarray:
    """Frame one packet: MAGIC hdr <stuffed payload> MAGIC crc."""
    payload = np.asarray(payload, dtype=np.uint32).ravel()
    header = [MAGIC, pack_header(dest, payload.size)]
    # Word stuffing: a literal MAGIC in the payload is sent as MAGIC MAGIC.
    reps = np.where(payload == MAGIC, 2, 1)
    stuffed = np.repeat(payload, reps)
    footer = [MAGIC, _crc(payload)]
    return np.concatenate([np.array(header, np.uint32), stuffed,
                           np.array(footer, np.uint32)])


def _parse_packet(stream: np.ndarray, i: int) -> tuple[int, np.ndarray, int]:
    """Parse one packet at word ``i``; returns (dest, payload, next_index).
    Raises ValueError on any framing/checksum violation."""
    n = stream.size
    if stream[i] != MAGIC or i + 1 >= n:
        raise ValueError(f"bad SOP framing at word {i}")
    dest, length = unpack_header(stream[i + 1])
    i += 2
    payload = np.empty(length, np.uint32)
    k = 0
    while k < length:
        if i >= n:
            raise ValueError("truncated payload")
        w = stream[i]
        if w == MAGIC:
            if i + 1 < n and stream[i + 1] == MAGIC:  # escaped literal
                payload[k] = MAGIC
                i += 2
                k += 1
                continue
            raise ValueError(f"unexpected control sequence at word {i}")
        payload[k] = w
        i += 1
        k += 1
    if i + 2 > n or stream[i] != MAGIC:
        raise ValueError(f"bad EOP framing at word {i}")
    if stream[i + 1] != _crc(payload):
        raise ValueError("checksum mismatch")
    return dest, payload, i + 2


def decode_stream(stream: np.ndarray, *,
                  resync: bool = False) -> list[tuple[int, np.ndarray]]:
    """Inverse of a concatenation of ``encode_packet`` outputs.

    Returns [(dest, payload), ...].  Raises ValueError on malformed input
    (bad framing or checksum) — the hardware would drop the packet and raise
    a LO|FA|MO transmission-error flag instead.

    ``resync=True`` models that hardware behaviour: a packet that fails to
    parse is dropped and the receiver slides forward to the next MAGIC
    candidate, re-locking on the first word sequence that parses as a
    whole packet (framing AND checksum).  This is exactly what the word
    stuffing exists for (§2.3): because a literal MAGIC can only ever
    appear doubled inside a payload, packet boundaries stay recoverable
    after mid-stream corruption — every intact packet beyond the damage
    is returned.
    """
    stream = np.asarray(stream, dtype=np.uint32).ravel()
    out: list[tuple[int, np.ndarray]] = []
    i = 0
    n = stream.size
    while i < n:
        try:
            dest, payload, i = _parse_packet(stream, i)
        except ValueError:
            if not resync:
                raise
            # drop and re-lock: next MAGIC strictly past the failed sync
            nxt = i + 1
            while nxt < n and stream[nxt] != MAGIC:
                nxt += 1
            if nxt >= n:
                break
            i = nxt
            continue
        out.append((dest, payload))
    return out


# ----------------------------------------------------------------------------
# Analytic efficiency model (§2.3).
#
#   eta(P) = P / (P + OVERHEAD_WORDS) * (1 - SYNC_FRACTION)
#
# Operating point calibrated to the paper: P = 16 payload words/packet with 4
# framing words (MAGIC SOP hdr | MAGIC EOP crc counted as 4 amortized control
# words beyond payload+hdr/crc data) and 2% of wire words spent on periodic
# clock-compensation/sync symbols:
#
#   16/(16+4) * (1 - 0.02) = 0.8 * 0.98 = 0.784          (paper: 0.784)
# ----------------------------------------------------------------------------

FRAME_OVERHEAD_WORDS = 4
SYNC_FRACTION = 0.02
DEFAULT_PAYLOAD_WORDS = 16


def protocol_efficiency(payload_words: int = DEFAULT_PAYLOAD_WORDS,
                        p_magic: float = 2.0**-32,
                        overhead_words: int = FRAME_OVERHEAD_WORDS,
                        sync_fraction: float = SYNC_FRACTION) -> float:
    """Expected wire efficiency for packets of ``payload_words`` words."""
    stuff = payload_words * p_magic  # expected extra escape words
    eta_frame = payload_words / (payload_words + overhead_words + stuff)
    return eta_frame * (1.0 - sync_fraction)


def measured_efficiency(payload: np.ndarray, packet_words: int) -> float:
    """Wire efficiency actually achieved by the codec on ``payload``."""
    payload = np.asarray(payload, dtype=np.uint32).ravel()
    total_wire = 0
    for start in range(0, payload.size, packet_words):
        pkt = payload[start:start + packet_words]
        total_wire += encode_packet(pkt).size
    # Periodic clock-compensation/sync symbols consume SYNC_FRACTION of wire.
    total_wire = total_wire / (1.0 - SYNC_FRACTION)
    return payload.size / total_wire


def channel_footprint_bytes(link: hw.ApenetLinkSpec = hw.APELINK_28G,
                            credit_loop_s: float = 14.3e-6) -> float:
    """Flow-control buffering per channel = bandwidth-delay product.

    Calibrated: 2.8 GB/s x 14.3 us = ~40 KB (paper: "memory footprint
    limited to ~40 KB per channel").
    """
    return link.channel_bandwidth * credit_loop_s


def sustained_bandwidth(link: hw.ApenetLinkSpec = hw.APELINK_28G,
                        payload_words: int = DEFAULT_PAYLOAD_WORDS) -> float:
    """Payload bandwidth after protocol overhead (bytes/s).

    28 Gbps raw -> 2.8 GB/s channel -> x0.784 -> ~2.2 GB/s (Fig 3c plateau).
    """
    return link.channel_bandwidth * protocol_efficiency(payload_words)


# ----------------------------------------------------------------------------
# Fig 3 latency / bandwidth model.
#
# Calibrated against the paper's headline numbers:
#   * GPU-to-GPU one-way latency, small msg, P2P:      ~8.2 us
#   * same, without P2P (host staging):                ~16.8 us
#   * same, InfiniBand + MVAPICH:                      ~17.4 us
#   * host-to-host is ~30% lower than GPU-involved:    ~6.3 us
#   * link payload plateau:                            ~2.2 GB/s
#   * GPU-outbound (GPU mem *read* over P2P) plateau:  ~1.4 GB/s
# ----------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class NetModel:
    link: hw.ApenetLinkSpec = hw.APELINK_28G
    host_if: hw.HostIfSpec = hw.PCIE_GEN2_X8
    t_inject: float = 3.9e-6       # SW descriptor + card injection, one side
    t_receive: float = 2.3e-6      # RX dispatch incl. HW TLB hit (see core.tlb)
    t_hop: float = 0.12e-6         # per-router transit
    gpu_touch_overhead: float = 0.94e-6  # extra cost when GPU is an endpoint (P2P)
    stage_overhead: float = 10.45e-6     # cudaMemcpy + staging pipeline setup
    ib_small_latency: float = 17.4e-6    # MVAPICH GPU-GPU small-message
    # MVAPICH GPU-GPU staging pipeline effective bandwidth, calibrated so the
    # APEnet+ P2P advantage holds "for message size up to 128 KB" (Fig 3b)
    # given that the P2P TX side is read-capped inside the GPU (Fig 3c).
    ib_bandwidth: float = 1.55e9
    gpu_read_cap: float = 1.4e9          # GPU-outbound P2P read bottleneck

    # -- latency -------------------------------------------------------------
    def latency(self, nbytes: int, *, src_gpu: bool = False,
                dst_gpu: bool = False, hops: int = 1, p2p: bool = True,
                fabric: str = "apenet") -> float:
        """One-way latency (seconds) for an ``nbytes`` message."""
        if fabric == "ib":
            return self.ib_small_latency + nbytes / self.ib_bandwidth
        bw = sustained_bandwidth(self.link)
        t = self.t_inject + self.t_receive + hops * self.t_hop
        t += nbytes / bw
        if p2p:
            t += self.gpu_touch_overhead * (int(src_gpu) + int(dst_gpu))
            if src_gpu:  # GPU memory read bottleneck (Fig 3c, GPU-outbound)
                t += max(0.0, nbytes / self.gpu_read_cap - nbytes / bw)
        else:
            # staging through host memory on each GPU endpoint
            for is_gpu in (src_gpu, dst_gpu):
                if is_gpu:
                    t += self.stage_overhead / 2 + nbytes / self.host_if.effective_bandwidth
        return t

    def roundtrip(self, nbytes: int, **kw) -> float:
        return 2.0 * self.latency(nbytes, **kw)

    # -- bandwidth (Fig 3c) ----------------------------------------------------
    def bandwidth(self, nbytes: int, **kw) -> float:
        return nbytes / self.latency(nbytes, **kw)
