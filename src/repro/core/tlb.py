"""Translation Look-aside Buffer — paper §2.2 (Fig 2).

APEnet+ moved virtual-to-physical translation of RDMA target addresses from
the embedded Nios II soft-CPU (slow path) into a hardware TLB on the FPGA
(fast path), gaining up to 60% receive bandwidth.

On the TPU adaptation this shows up twice:

* ``Tlb`` below — a set-associative, LRU registration cache used by the
  serving engine and the RDMA layer to translate logical buffer pages
  (virtual) into device pages (physical).  Its *cost model* reproduces the
  paper's Fig 2 speedup: a hit bypasses the "Nios II" path entirely.

* the Pallas ``paged_attention`` kernel (``repro.kernels``) — the page-table
  lookup happens inside the kernel's index_map, i.e. translation at
  "hardware" level, vs. the reference path that gathers pages with XLA ops
  first ("software" level).  See kernels/paged_attention.py.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Callable

# Cost model constants (seconds per translation), calibrated so that on the
# paper's synthetic receive benchmark a hot TLB yields a ~60% bandwidth gain
# (paper: "speedup of up to 60% in bandwidth ... measured").  The Nios II
# firmware walk took O(microseconds); the HW TLB answers in a few cycles.
T_NIOS_WALK = 1.2e-6   # software page walk on the embedded CPU
T_HW_HIT = 0.05e-6     # hardware TLB hit (a few 250 MHz cycles)
PAGE_BYTES = 4096


@dataclasses.dataclass
class TlbStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0

    @property
    def accesses(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        return self.hits / self.accesses if self.accesses else 0.0


class Tlb:
    """Set-associative TLB with per-set LRU replacement.

    ``entries`` total entries split into ``ways``-associative sets.  The
    translate() method returns (physical_page, cost_seconds); the cost is the
    Fig 2 model: HW hit vs Nios II walk + fill.
    """

    def __init__(self, entries: int = 512, ways: int = 4,
                 walk: Callable[[int], int] | None = None) -> None:
        if entries % ways:
            raise ValueError("entries must be a multiple of ways")
        self.ways = ways
        self.nsets = entries // ways
        self._sets: list[OrderedDict[int, int]] = [OrderedDict()
                                                   for _ in range(self.nsets)]
        # Default "page table": identity translation (tests override).
        self._walk = walk or (lambda vpage: vpage)
        self.stats = TlbStats()

    def _set_of(self, vpage: int) -> OrderedDict[int, int]:
        return self._sets[vpage % self.nsets]

    def translate(self, vaddr: int) -> tuple[int, float]:
        """Translate a byte address; returns (paddr, model_cost_seconds)."""
        vpage, off = divmod(vaddr, PAGE_BYTES)
        s = self._set_of(vpage)
        if vpage in s:
            s.move_to_end(vpage)  # LRU touch
            self.stats.hits += 1
            return s[vpage] * PAGE_BYTES + off, T_HW_HIT
        # Miss: Nios II walk, then fill (possibly evicting the set's LRU).
        self.stats.misses += 1
        ppage = self._walk(vpage)
        if len(s) >= self.ways:
            s.popitem(last=False)
            self.stats.evictions += 1
        s[vpage] = ppage
        return ppage * PAGE_BYTES + off, T_NIOS_WALK + T_HW_HIT

    def invalidate(self, vaddr: int | None = None) -> None:
        """Shoot down one page (or the whole TLB) on deregistration."""
        if vaddr is None:
            for s in self._sets:
                s.clear()
            return
        vpage = vaddr // PAGE_BYTES
        self._set_of(vpage).pop(vpage, None)

    # -- Fig 2 receive-bandwidth model ----------------------------------------
    def receive_bandwidth(self, nbytes: int, wire_bandwidth: float,
                          hit_rate: float | None = None) -> float:
        """Effective RX bandwidth when every page needs translation.

        ``hit_rate=None`` uses the *measured* stats; otherwise the analytic
        model with the given hit rate is applied.  Translation is on the
        critical path of the RX DMA dispatch (paper §2.2).
        """
        pages = max(1, nbytes // PAGE_BYTES)
        hr = self.stats.hit_rate if hit_rate is None else hit_rate
        t_translate = pages * (hr * T_HW_HIT + (1 - hr) * (T_NIOS_WALK + T_HW_HIT))
        t_wire = nbytes / wire_bandwidth
        return nbytes / (t_wire + t_translate)
