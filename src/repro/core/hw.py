"""Hardware constants for roofline analysis and the APElink what-if study.

The runtime target is a TPU v5e pod (the container itself is CPU-only; all
performance numbers are *derived* from compiled HLO, not measured wall-clock).

The paper's §6 next-generation study (PCIe Gen3, 56 Gb/s links) is expressed
here as alternative hardware constant sets so the roofline can be re-run
under "current" vs "next-gen" link assumptions.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    """Per-chip performance envelope used by the three-term roofline."""

    name: str
    peak_flops_bf16: float  # FLOP/s
    hbm_bandwidth: float    # bytes/s
    ici_link_bandwidth: float  # bytes/s per link direction
    ici_links: int          # off-chip torus links per chip
    hbm_bytes: int          # HBM capacity in bytes
    vmem_bytes: int         # on-chip vector memory

    @property
    def ici_aggregate_bandwidth(self) -> float:
        return self.ici_link_bandwidth * self.ici_links


# Primary target: TPU v5e (values fixed by the assignment).
TPU_V5E = ChipSpec(
    name="tpu-v5e",
    peak_flops_bf16=197e12,
    hbm_bandwidth=819e9,
    ici_link_bandwidth=50e9,
    ici_links=4,            # 2D torus per pod; the "pod" axis rides DCN/optical
    hbm_bytes=16 * 1024**3,
    vmem_bytes=128 * 1024**2,
)

# Paper-era accelerator (Fermi/Kepler-class) at a conservative 40% MFU —
# the ONE modelled compute rate every paper-twin benchmark prices against:
# benchmarks/overlap.py (backward compute behind the bucketed sync) and the
# serving cluster's re-prefill stall model (benchmarks/migration.py gate).
PAPER_GPU_PEAK_FLOPS = 4.0e12
PAPER_GPU_MFU = 0.4
PAPER_GPU_EFF_FLOPS = PAPER_GPU_PEAK_FLOPS * PAPER_GPU_MFU

# ----------------------------------------------------------------------------
# APEnet+ board generations (paper §2.3, §3, §6) — used by the paper-claims
# benchmarks, NOT by the TPU roofline.  Bandwidths in bytes/s.
# ----------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ApenetLinkSpec:
    """One APElink channel: N bonded serial lanes + encoding + protocol."""

    name: str
    lanes: int
    lane_gbps: float          # raw line rate per lane (Gbit/s)
    encoding_efficiency: float  # physical coding (8b/10b = 0.8, 128b/130b ~ 0.985)

    @property
    def raw_bandwidth(self) -> float:
        """Raw aggregated line rate, bytes/s (the paper's '28 Gbps' number)."""
        return self.lanes * self.lane_gbps * 1e9 / 8.0

    @property
    def channel_bandwidth(self) -> float:
        """Post-encoding channel payload capacity, bytes/s (~2.8 GB/s @28Gbps)."""
        return self.raw_bandwidth * self.encoding_efficiency


# Paper operating point: 4 lanes x 7.0 Gbps, 8b/10b -> 2.8 GB/s channel;
# after APElink protocol efficiency 0.784 -> ~2.2 GB/s observed (Fig 3c).
APELINK_28G = ApenetLinkSpec("apelink-28g", lanes=4, lane_gbps=7.0,
                             encoding_efficiency=0.8)
# §6 next-gen: Stratix V, 4 x 14.1 Gbps, QSFP+ (64b/66b-class encoding).
APELINK_56G = ApenetLinkSpec("apelink-56g", lanes=4, lane_gbps=14.1,
                             encoding_efficiency=64.0 / 66.0)
# §6 preliminary measurement: 11.3 Gbps/lane over 40G-certified cables.
APELINK_45G = ApenetLinkSpec("apelink-45g-meas", lanes=4, lane_gbps=11.3,
                             encoding_efficiency=64.0 / 66.0)


@dataclasses.dataclass(frozen=True)
class HostIfSpec:
    """PCIe host interface generations (paper §2.1 / §6)."""

    name: str
    lanes: int
    lane_gbps: float
    encoding_efficiency: float

    @property
    def raw_bandwidth(self) -> float:
        return self.lanes * self.lane_gbps * 1e9 / 8.0

    @property
    def effective_bandwidth(self) -> float:
        return self.raw_bandwidth * self.encoding_efficiency


PCIE_GEN2_X8 = HostIfSpec("pcie-gen2-x8", 8, 5.0, 0.8)           # 4.0 GB/s
PCIE_GEN3_X8 = HostIfSpec("pcie-gen3-x8", 8, 8.0, 128.0 / 130.0)  # ~7.9 GB/s
