#!/usr/bin/env python3
"""Bench-regression gate: diff the two newest ``BENCH_<n>.json`` snapshots
(written by ``benchmarks/run.py``) and fail on regression of gated metrics.

The contract, per benchmark row:

* **direction** — ``"gate": "higher"`` (bigger is better — speedups,
  reductions, efficiencies) or ``"gate": "lower"`` (smaller is better —
  times, costs).  The boolean spelling ``"higher_is_better": true|false``
  is accepted as an equivalent (ArchGym-style metric descriptors use it).
* **tolerance** — ``"tol": 0.15`` overrides the global ``--threshold``
  (default 10%) for that one metric: tight gates (``tol: 0.0`` for the
  autotuner's bitwise determinism metric) and loose ones (searched-gain
  metrics that legitimately wander with the search budget) coexist in one
  snapshot.

Ungated rows are informational and never fail the gate; gated metrics
present in only one snapshot (a bench was added/removed or a different
lane ran) are reported but don't fail.

Besides the plain-text report, the gate renders a markdown summary table
— printed to stdout, and appended to ``$GITHUB_STEP_SUMMARY`` when that
file is set (the GitHub Actions job-summary panel).

    python scripts/bench_gate.py [--dir DIR] [--threshold 0.10]

Exit 0 when no gated metric regressed past its tolerance (or when fewer
than two snapshots exist — the first run records the baseline), exit 1
otherwise.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, REPO)

from benchmarks.run import list_snapshots  # noqa: E402  (shared discovery)


def row_direction(row: dict) -> str | None:
    """"higher" | "lower" | None, from either metadata spelling."""
    gate = row.get("gate")
    if gate in ("higher", "lower"):
        return gate
    hib = row.get("higher_is_better")
    if isinstance(hib, bool):
        return "higher" if hib else "lower"
    return None


def gated_rows(snapshot: dict) -> dict[tuple[str, str], dict]:
    out = {}
    for row in snapshot.get("rows", []):
        if row_direction(row) is not None:
            out[(row["bench"], row["metric"])] = row
    return out


def compare(prev: dict, cur: dict, threshold: float)\
        -> tuple[list, list, list]:
    """Returns (report lines, markdown table rows, regressions)."""
    prows, crows = gated_rows(prev), gated_rows(cur)
    lines, md, regressions = [], [], []
    for key in sorted(crows):
        bench, metric = key
        row = crows[key]
        direction = row_direction(row)
        tol = float(row.get("tol", threshold))
        if key not in prows:
            lines.append(f"  new    {bench}.{metric} = "
                         f"{row['value']:.6g} (baseline recorded)")
            md.append((f"{bench}.{metric}", "—", f"{row['value']:.6g}",
                       "—", direction, f"{tol:.0%}", "new"))
            continue
        base, new = float(prows[key]["value"]), float(row["value"])
        if base == 0.0:
            delta = 0.0 if new == 0.0 else float("inf")
        else:
            delta = (new - base) / abs(base)
        worse = (-delta if direction == "higher" else delta)
        tag = "ok    "
        status = "ok"
        if worse > tol:
            tag, status = "REGRESS", "**REGRESS**"
            regressions.append(
                f"{bench}.{metric}: {base:.6g} -> {new:.6g} "
                f"({delta * 100:+.1f}%, {direction}-is-better, "
                f"tolerance {tol * 100:.0f}%)")
        lines.append(f"  {tag} {bench}.{metric}: {base:.6g} -> {new:.6g} "
                     f"({delta * 100:+.1f}%, {direction}, "
                     f"tol {tol * 100:.0f}%)")
        md.append((f"{bench}.{metric}", f"{base:.6g}", f"{new:.6g}",
                   f"{delta * 100:+.1f}%", direction, f"{tol:.0%}", status))
    for key in sorted(set(prows) - set(crows)):
        lines.append(f"  gone   {key[0]}.{key[1]} (not in current run)")
        md.append((f"{key[0]}.{key[1]}", f"{prows[key]['value']:.6g}", "—",
                   "—", row_direction(prows[key]), "—", "gone"))
    return lines, md, regressions


def markdown_summary(md_rows: list, pseq: int, cseq: int,
                     regressions: list) -> str:
    verdict = (f"❌ {len(regressions)} regression(s)" if regressions
               else "✅ no gated-metric regressions")
    head = (f"### Bench gate: `BENCH_{pseq}.json` → `BENCH_{cseq}.json`\n\n"
            f"{verdict}\n\n")
    table = ["| metric | prev | cur | Δ | direction | tol | status |",
             "|---|---:|---:|---:|---|---:|---|"]
    for name, base, new, delta, direction, tol, status in md_rows:
        table.append(f"| `{name}` | {base} | {new} | {delta} "
                     f"| {direction} | {tol} | {status} |")
    return head + "\n".join(table) + "\n"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dir", default=os.environ.get("BENCH_DIR") or REPO,
                    help="directory holding BENCH_<n>.json snapshots")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="default relative regression tolerance for rows "
                         "without a per-metric 'tol' (default 0.10)")
    args = ap.parse_args(argv)
    snaps = list_snapshots(args.dir)
    if len(snaps) < 2:
        have = snaps[-1][1] if snaps else "none"
        print(f"[bench-gate] <2 snapshots in {args.dir} (latest: {have}); "
              "baseline recorded, nothing to diff")
        return 0
    (pseq, ppath), (cseq, cpath) = snaps[-2], snaps[-1]
    with open(ppath) as f:
        prev = json.load(f)
    with open(cpath) as f:
        cur = json.load(f)
    print(f"[bench-gate] BENCH_{pseq}.json -> BENCH_{cseq}.json "
          f"(default threshold {args.threshold * 100:.0f}%)")
    lines, md_rows, regressions = compare(prev, cur, args.threshold)
    for ln in lines:
        print(ln)
    summary = markdown_summary(md_rows, pseq, cseq, regressions)
    step_summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if step_summary:
        with open(step_summary, "a") as f:
            f.write(summary)
    else:
        print("\n" + summary)
    if regressions:
        print("\nBENCH REGRESSIONS:", file=sys.stderr)
        for r in regressions:
            print("  " + r, file=sys.stderr)
        return 1
    print("[bench-gate] no gated-metric regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
