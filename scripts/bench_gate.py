#!/usr/bin/env python3
"""Bench-regression gate: diff the two newest ``BENCH_<n>.json`` snapshots
(written by ``benchmarks/run.py``) and fail on >10% regression of gated
metrics.

The contract: a benchmark row may declare ``"gate": "higher"`` (bigger is
better — speedups, reductions, efficiencies) or ``"gate": "lower"``
(smaller is better — times, costs).  Ungated rows are informational and
never fail the gate; gated metrics present in only one snapshot (a bench
was added/removed or a different lane ran) are reported but don't fail.

    python scripts/bench_gate.py [--dir DIR] [--threshold 0.10]

Exit 0 when no gated metric regressed past the threshold (or when fewer
than two snapshots exist — the first run records the baseline), exit 1
otherwise.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, REPO)

from benchmarks.run import list_snapshots  # noqa: E402  (shared discovery)


def gated_rows(snapshot: dict) -> dict[tuple[str, str], dict]:
    out = {}
    for row in snapshot.get("rows", []):
        if row.get("gate") in ("higher", "lower"):
            out[(row["bench"], row["metric"])] = row
    return out


def compare(prev: dict, cur: dict, threshold: float) -> tuple[list, list]:
    """Returns (report lines, regressions)."""
    prows, crows = gated_rows(prev), gated_rows(cur)
    lines, regressions = [], []
    for key in sorted(crows):
        bench, metric = key
        if key not in prows:
            lines.append(f"  new    {bench}.{metric} = "
                         f"{crows[key]['value']:.6g} (baseline recorded)")
            continue
        base, new = float(prows[key]["value"]), float(crows[key]["value"])
        direction = crows[key]["gate"]
        if base == 0.0:
            delta = 0.0 if new == 0.0 else float("inf")
        else:
            delta = (new - base) / abs(base)
        worse = (-delta if direction == "higher" else delta)
        tag = "ok    "
        if worse > threshold:
            tag = "REGRESS"
            regressions.append(
                f"{bench}.{metric}: {base:.6g} -> {new:.6g} "
                f"({delta * 100:+.1f}%, {direction}-is-better, "
                f"threshold {threshold * 100:.0f}%)")
        lines.append(f"  {tag} {bench}.{metric}: {base:.6g} -> {new:.6g} "
                     f"({delta * 100:+.1f}%, {direction})")
    for key in sorted(set(prows) - set(crows)):
        lines.append(f"  gone   {key[0]}.{key[1]} (not in current run)")
    return lines, regressions


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dir", default=os.environ.get("BENCH_DIR") or REPO,
                    help="directory holding BENCH_<n>.json snapshots")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="relative regression tolerance (default 0.10)")
    args = ap.parse_args(argv)
    snaps = list_snapshots(args.dir)
    if len(snaps) < 2:
        have = snaps[-1][1] if snaps else "none"
        print(f"[bench-gate] <2 snapshots in {args.dir} (latest: {have}); "
              "baseline recorded, nothing to diff")
        return 0
    (pseq, ppath), (cseq, cpath) = snaps[-2], snaps[-1]
    with open(ppath) as f:
        prev = json.load(f)
    with open(cpath) as f:
        cur = json.load(f)
    print(f"[bench-gate] BENCH_{pseq}.json -> BENCH_{cseq}.json "
          f"(threshold {args.threshold * 100:.0f}%)")
    lines, regressions = compare(prev, cur, args.threshold)
    for ln in lines:
        print(ln)
    if regressions:
        print("\nBENCH REGRESSIONS:", file=sys.stderr)
        for r in regressions:
            print("  " + r, file=sys.stderr)
        return 1
    print("[bench-gate] no gated-metric regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
