#!/usr/bin/env bash
# CI driver — the single source of truth for local runs AND the GitHub
# workflows (.github/workflows/ci.yml and nightly.yml invoke this same
# script).
#
#   scripts/ci.sh fast     # PR lane:    lint -> fast tests (-m "not slow")
#                          #             -> quick benches (incl. the
#                          #             20-step autotune smoke) -> gate
#   scripts/ci.sh full     # main lane:  lint -> full tier-1 tests
#                          #             -> all benches -> gate
#   scripts/ci.sh nightly  # nightly:    full lane budgets + the full
#                          #             design-space search with packet
#                          #             re-scoring; best_configs.json +
#                          #             BENCH_*.json become artifacts
#
# Every step is timed; on failure the script names the failing step and
# prints the timing table collected so far, so a red run localises itself
# from the last log lines alone.
#
# The bench gate diffs the BENCH_<n>.json snapshot this run writes against
# the previous one (scripts/bench_gate.py; per-metric direction + tolerance,
# default 10%).  The first run just records the baseline.
set -euo pipefail
cd "$(dirname "$0")/.."
LANE="${1:-fast}"
case "$LANE" in fast|full|nightly) ;; *)
    echo "usage: scripts/ci.sh [fast|full|nightly]" >&2; exit 2 ;;
esac

STEP_NAMES=()
STEP_SECS=()

timing_table() {
    local i
    echo "[ci] step timings:"
    for i in "${!STEP_NAMES[@]}"; do
        printf '[ci]   %-24s %5ss\n' "${STEP_NAMES[$i]}" "${STEP_SECS[$i]}"
    done
}

step() {
    local name="$1"; shift
    echo "[ci] >> $name"
    local t0=$SECONDS
    if ! "$@"; then
        local dt=$((SECONDS - t0))
        STEP_NAMES+=("$name"); STEP_SECS+=("$dt")
        timing_table
        echo "[ci] FAILED at step '$name' after ${dt}s ($LANE lane)" >&2
        exit 1
    fi
    local dt=$((SECONDS - t0))
    STEP_NAMES+=("$name"); STEP_SECS+=("$dt")
    echo "[ci] << $name (${dt}s)"
}

# Editable install makes `import repro` work without PYTHONPATH; keep the
# PYTHONPATH fallback so the script also works where pip cannot write.
pip install -e . --no-deps --no-build-isolation -q 2>/dev/null \
    || echo "[ci] editable install unavailable; falling back to PYTHONPATH"
# dev extras (hypothesis property tests, ruff lint) are best-effort
# offline: tier-1 collects cleanly without them via pytest.importorskip,
# and the lint step below degrades to a skip when ruff is missing.
pip install -q pytest hypothesis ruff 2>/dev/null \
    || echo "[ci] dev extras unavailable offline; lint/property tests may skip"

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if command -v ruff >/dev/null 2>&1; then
    # hard failure when ruff is present (CI always has it; offline dev
    # boxes without it skip with a warning)
    step "lint" ruff check src tests benchmarks scripts
else
    echo "[ci] ruff not installed; skipping lint (best-effort offline)"
fi

if [ "$LANE" = "fast" ]; then
    # fast tests: -m "not slow", small hypothesis budget
    step "tests-fast" env HYPOTHESIS_PROFILE=ci \
        python -m pytest -x -q -m "not slow"
    # quick benches: simscale smoke skips the packet baseline; the
    # autotune smoke caps the design-space search at 20 fluid steps
    # (seeded, genetic agent only) with the winner still packet-verified;
    # the trace-replay smoke (TRACE_FAST=1) runs the 16-node SLO replay
    # and skips the 512-node nightly-scale one; the closed-loop QoS
    # smoke (QOSCTL_FAST=1) keeps all three gated rows (gain,
    # preemption, quiescence) and skips the default-weights arm; the
    # telemetry smoke (TELEMETRY_FAST=1) keeps all exact-0 gates
    # (invisibility, counter cross-check, trace schema/roundtrip) and
    # skips the 512-node enabled-overhead measurement
    step "benches-quick" env SIMSCALE_FAST=1 AUTOTUNE_FAST=1 TRACE_FAST=1 \
        QOSCTL_FAST=1 TELEMETRY_FAST=1 \
        python -m benchmarks.run overlap dma_overlap fabric_cost \
        migration contention qos simscale autotune trace_replay qosctl \
        telemetry
else
    step "tests-full" python -m pytest -x -q
    if [ "$LANE" = "nightly" ]; then
        # the full ArchGym-style search: every agent, 120-step budgets,
        # top-k packet re-score — refreshes best_configs.json, which the
        # nightly workflow uploads (with the BENCH snapshot) as artifacts
        step "benches-nightly" env AUTOTUNE_NIGHTLY=1 \
            python -m benchmarks.run
        # export the seeded 16-node replay timeline as Chrome-trace JSON
        # and schema-check it; the nightly workflow uploads the file as
        # an artifact next to best_configs.json, so every night leaves a
        # Perfetto-loadable record of the fabric under the SLO replay
        step "fabric-trace" python scripts/fabric_trace.py \
            --nodes 16 --out fabric.trace.json
        step "trace-validate" python scripts/fabric_trace.py \
            --validate fabric.trace.json
    else
        step "benches-all" python -m benchmarks.run
    fi
fi

step "bench-gate" python scripts/bench_gate.py

timing_table
echo "[ci] OK ($LANE lane)"
