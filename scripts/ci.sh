#!/usr/bin/env bash
# Tier-1 CI: install the package (editable, offline-safe) + dev deps where
# the index is reachable, then run the tier-1 test command and the fabric
# cost-model benchmark gate.
set -euo pipefail
cd "$(dirname "$0")/.."

# Editable install makes `import repro` work without PYTHONPATH; keep the
# PYTHONPATH fallback so the script also works where pip cannot write.
pip install -e . --no-deps --no-build-isolation -q 2>/dev/null \
    || echo "[ci] editable install unavailable; falling back to PYTHONPATH"
# dev extras (hypothesis property tests) are best-effort: tier-1 collects
# cleanly without them via pytest.importorskip
pip install -q pytest hypothesis 2>/dev/null \
    || echo "[ci] dev extras unavailable offline; property tests skipped"

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "[ci] tier-1 tests"
python -m pytest -x -q

echo "[ci] fabric cost-model benchmark gate"
python -m benchmarks.run fabric_cost

echo "[ci] OK"
