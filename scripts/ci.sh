#!/usr/bin/env bash
# CI driver — the single source of truth for local runs AND the GitHub
# workflow (.github/workflows/ci.yml invokes this same script).
#
#   scripts/ci.sh fast   # PR lane:   lint -> fast tests (-m "not slow")
#                        #            -> quick benches -> regression gate
#   scripts/ci.sh full   # main lane: lint -> full tier-1 tests
#                        #            -> all benches -> regression gate
#
# The bench gate diffs the BENCH_<n>.json snapshot this run writes against
# the previous one (scripts/bench_gate.py; >10% regression of gated
# metrics fails).  The first run just records the baseline.
set -euo pipefail
cd "$(dirname "$0")/.."
LANE="${1:-fast}"

# Editable install makes `import repro` work without PYTHONPATH; keep the
# PYTHONPATH fallback so the script also works where pip cannot write.
pip install -e . --no-deps --no-build-isolation -q 2>/dev/null \
    || echo "[ci] editable install unavailable; falling back to PYTHONPATH"
# dev extras (hypothesis property tests, ruff lint) are best-effort
# offline: tier-1 collects cleanly without them via pytest.importorskip,
# and the lint step below degrades to a skip when ruff is missing.
pip install -q pytest hypothesis ruff 2>/dev/null \
    || echo "[ci] dev extras unavailable offline; lint/property tests may skip"

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "[ci] lint (ruff)"
if command -v ruff >/dev/null 2>&1; then
    # hard failure when ruff is present (CI always has it; offline dev
    # boxes without it skip with a warning)
    ruff check src tests benchmarks scripts
else
    echo "[ci] ruff not installed; skipping lint (best-effort offline)"
fi

if [ "$LANE" = "full" ]; then
    echo "[ci] tier-1 tests (full lane)"
    python -m pytest -x -q
    echo "[ci] benchmarks (all modules)"
    python -m benchmarks.run
else
    echo "[ci] tier-1 tests (fast lane: -m 'not slow', small hypothesis budget)"
    HYPOTHESIS_PROFILE=ci python -m pytest -x -q -m "not slow"
    echo "[ci] benchmarks (quick set; simscale smoke skips the packet baseline)"
    SIMSCALE_FAST=1 python -m benchmarks.run overlap dma_overlap fabric_cost \
        migration contention qos simscale
fi

echo "[ci] bench regression gate"
python scripts/bench_gate.py

echo "[ci] OK ($LANE lane)"
