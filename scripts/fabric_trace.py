#!/usr/bin/env python3
"""Export (or validate) a Perfetto-loadable fabric trace.

Runs the seeded 16-node serving trace replay (the same workload as
``benchmarks/trace_replay.py``'s smoke lane) with a ``Telemetry`` hub
attached, writes the event timeline as Chrome-trace JSON — loadable at
``ui.perfetto.dev`` or ``chrome://tracing`` — and prints the counter
summary table.  Fully deterministic: the same ``--seed`` produces a
byte-identical ``.trace.json``.

    python scripts/fabric_trace.py --out fabric.trace.json
    python scripts/fabric_trace.py --nodes 16 --requests 240 --seed 11
    python scripts/fabric_trace.py --validate fabric.trace.json

``--validate FILE`` skips the replay and schema-checks an existing
trace file instead (the nightly CI lane validates its own export).
Exit 0 on success, 1 on schema violations or a failed export.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))
sys.path.insert(0, REPO)

DIMS_BY_NODES = {16: (4, 4), 64: (4, 4, 4), 512: (8, 8, 8)}


def export(out_path: str, *, nodes: int, requests: int, seed: int,
           fidelity: str) -> int:
    from repro.core import fabric
    from benchmarks.trace_replay import _cluster, _trace
    from repro.serving.trace import replay

    dims = DIMS_BY_NODES.get(nodes)
    if dims is None:
        print(f"unsupported --nodes {nodes}; known: "
              f"{sorted(DIMS_BY_NODES)}", file=sys.stderr)
        return 1
    tel = fabric.Telemetry()
    cl = _cluster(dims, fidelity=fidelity, queue_limit=48)
    cl.telemetry = tel
    cl.sim.telemetry = tel
    for node in cl.nodes.values():
        node.lm.endpoint.telemetry = tel
    tr = _trace(requests, nodes, 0.92, seed)
    report = replay(cl, tr, rebalance="proactive")
    blob = tel.to_perfetto()
    errs = fabric.validate_perfetto(json.loads(blob))
    if errs:
        for e in errs:
            print(f"schema: {e}", file=sys.stderr)
        return 1
    with open(out_path, "w") as f:
        f.write(blob)
    print(f"wrote {out_path}: {len(blob)} bytes, "
          f"{tel.n_events} events ({tel.dropped} dropped)")
    print(f"replay: {report.n_finished}/{report.n_requests} finished, "
          f"tpt p99 {report.tpt_p99_s * 1e3:.2f} ms, "
          f"makespan {report.makespan_s:.2f} s")
    print()
    print(tel.summary_table())
    return 0


def validate(path: str) -> int:
    from repro.core import fabric
    try:
        with open(path) as f:
            obj = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"{path}: unreadable trace ({e})", file=sys.stderr)
        return 1
    errs = fabric.validate_perfetto(obj)
    if errs:
        for e in errs:
            print(f"{path}: {e}", file=sys.stderr)
        return 1
    n = len(obj.get("traceEvents", []))
    print(f"{path}: valid Chrome-trace JSON, {n} trace events")
    return 0


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--out", default="fabric.trace.json",
                   help="output trace path (default fabric.trace.json)")
    p.add_argument("--nodes", type=int, default=16,
                   help="cluster size: 16, 64 or 512 (default 16)")
    p.add_argument("--requests", type=int, default=240,
                   help="trace length (default 240)")
    p.add_argument("--seed", type=int, default=11,
                   help="trace seed (default 11, the smoke-lane seed)")
    p.add_argument("--fidelity", default="fluid",
                   choices=("packet", "fluid", "hybrid"))
    p.add_argument("--validate", metavar="FILE", default=None,
                   help="schema-check an existing trace file and exit")
    args = p.parse_args(argv)
    if args.validate is not None:
        return validate(args.validate)
    return export(args.out, nodes=args.nodes, requests=args.requests,
                  seed=args.seed, fidelity=args.fidelity)


if __name__ == "__main__":
    sys.exit(main())
