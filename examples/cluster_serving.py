"""Multi-node serving with live RDMA KV-page migration.

  PYTHONPATH=src python examples/cluster_serving.py

Walkthrough of the three cluster mechanisms:

  1. ROUTER     — requests are admitted to the least-loaded node of a
                  4-ring torus fabric carrying two serving replicas;
  2. MIGRATION  — a running request's KV pages move to another node as one
                  bulk dimension-ordered RDMA PUT (``put_pages`` over a
                  ``fabric.lower_p2p`` schedule) and decode resumes there
                  with bitwise-identical tokens;
  3. FAULT REROUTE — the direct link dies (LO|FA|MO feeds the fault map);
                  the next migration takes the BFS detour: more hops,
                  honestly higher modelled cost, same tokens.
"""
import numpy as np

import jax

from repro import configs
from repro.models import api
from repro.serving.cluster import ServingCluster, owners
from repro.serving.engine import Request
from repro.core.topology import Torus


def main() -> None:
    cfg = configs.get_reduced("qwen2-0.5b")
    model = api.get_model(cfg)
    params = model.init(jax.random.key(0))

    # 4-ring fabric, serving nodes at ranks 0 and 1 (2 and 3 route only)
    cluster = ServingCluster(cfg, params, torus=Torus((4,)),
                             node_ranks=(0, 1), max_batch=4, max_seq=64,
                             page_tokens=8)

    rng = np.random.default_rng(0)
    rids = list(range(4))
    for rid in rids:
        plen = int(rng.integers(5, 16))
        placed = cluster.submit(Request(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab, size=(plen,)).astype(np.int32),
            max_new_tokens=10))
        print(f"router: request {rid} (prompt {plen} tok) -> node {placed}")

    for _ in range(4):          # prefill + a few decode steps everywhere
        cluster.step()

    # -- live migration off node 0 -------------------------------------------
    rid = next(r.rid for r in cluster.nodes[0].engine.running.values())
    rep = cluster.migrate(rid, 1)
    print(f"\nmigrated request {rep.rid}: node {rep.src} -> {rep.dst}, "
          f"{rep.n_pages} pages / {rep.nbytes / 1e3:.1f} KB over "
          f"{rep.hops} hop(s)")
    print(f"  modelled PUT {rep.modelled_s * 1e6:.1f} us vs re-prefill "
          f"stall {rep.reprefill_s * 1e6:.1f} us")

    # -- the same move through a dead link ------------------------------------
    cluster.fail_link(0, 1)
    rid2 = next((r.rid for r in cluster.nodes[0].engine.running.values()),
                None)
    if rid2 is not None:
        rep2 = cluster.migrate(rid2, 1)
        print(f"\nlink (0,1) dead -> request {rep2.rid} rerouted over "
              f"{rep2.hops} hops (healthy route: {rep2.min_hops}); "
              f"rerouted={rep2.rerouted}")

    cluster.run_to_completion()
    st = cluster.stats()
    print(f"\nfinished {len(cluster.finished)}/{len(rids)} requests, "
          f"{st['n_migrations']} migrations "
          f"({st['migrated_bytes'] / 1e3:.1f} KB KV moved, "
          f"{st['rerouted_migrations']} rerouted)")
    for r, ns in st["nodes"].items():
        print(f"  node {r}: {ns['decode_steps']} decode steps, "
              f"tlb_hit_rate={ns['tlb_hit_rate']:.3f}")
    assert len(cluster.finished) == len(rids)
    assert owners(cluster, rids) == {rid: None for rid in rids}
    print("cluster serving OK")


if __name__ == "__main__":
    main()
