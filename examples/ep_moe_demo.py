"""Expert-parallel MoE over the torus all-to-all (§Perf H2 live).

  PYTHONPATH=src python examples/ep_moe_demo.py

Runs the same MoE layer three ways on 8 forced host devices and shows
they agree while communicating very differently:

  1. dense reference      — every expert on every token (no dispatch);
  2. global sort dispatch — one data-dependent scatter; under GSPMD the
     partitioner all-gathers the (T·K, d) token buffer (the baseline the
     roofline flagged 50× collective-bound);
  3. shard_map EP         — local routing + two explicit lax.all_to_all
     ops over 'model': the paper's dimension-ordered torus A2A.
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import dataclasses  # noqa: E402

import numpy as np  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro import configs  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402
from repro.models import moe  # noqa: E402
from repro.models.common import MoeCfg  # noqa: E402
from repro.parallel import sharding  # noqa: E402


def main() -> None:
    mesh = make_mesh((2, 4), ("data", "model"))
    cfg = dataclasses.replace(
        configs.get_config("olmoe-1b-7b").reduced(),
        moe=MoeCfg(n_experts=8, top_k=2, d_expert=32, capacity_factor=8.0),
        d_model=64, dtype=jnp.float32, moe_impl="ep_a2a")
    params = moe.init_moe(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(4, 16, cfg.d_model)) * 0.3, jnp.float32)

    y_global, _ = moe.apply_moe(cfg, params, x)
    sharding.set_runtime_mesh(mesh)
    try:
        with mesh:
            fn = jax.jit(lambda p, x: moe.apply_moe_ep(cfg, p, x))
            y_ep, _ = fn(params, x)
            hlo = fn.lower(params, x).compile().as_text()
    finally:
        sharding.set_runtime_mesh(None)

    print("EP output == global-dispatch output:",
          bool(jnp.allclose(y_ep, y_global, rtol=2e-4, atol=2e-4)))
    a2a = [ln.strip().split(" = ")[1][:60] for ln in hlo.splitlines()
           if "all-to-all(" in ln]
    print(f"explicit all-to-alls in the compiled EP program: {len(a2a)}")
    for line in a2a[:2]:
        print("   ", line)
    print("(8 experts live 2-per-shard on the 4-way 'model' axis; each",
          "shard routed its own tokens and exchanged capacity buffers",
          "over the torus — §2 of the paper as a MoE layer)")
    print("ep moe demo OK")


if __name__ == "__main__":
    main()
