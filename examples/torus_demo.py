"""The APEnet+ fabric itself: 3D torus RDMA + ring collectives demo.

  PYTHONPATH=src python examples/torus_demo.py

Shows the paper's communication layer as a library:
  * 3D-torus coordinate math, dimension-ordered routing, hop metrics;
  * one-sided RDMA put/get over mesh axes (shard_map + ppermute);
  * the bidirectional double-buffered ring all-reduce ("dual DMA engines")
    matching jax.lax.psum bit-for-bit in fp32;
  * the APElink efficiency / latency models reproducing the paper numbers.
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import numpy as np  # noqa: E402

import jax  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.core import apelink, collectives as C, jaxcompat, rdma  # noqa: E402
from repro.core.lofamo import awareness_time_model  # noqa: E402
from repro.core.topology import Torus  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402


def main() -> None:
    # --- topology: the QUonG 4x4x1 deployment --------------------------------
    t = Torus((4, 4, 1))
    print(f"QUonG torus {t.dims}: {t.size} nodes, diameter {t.diameter}, "
          f"{len(t.links())} links, bisection {t.bisection_links} links")
    src, dst = 0, t.rank((2, 3, 0))
    print(f"dimension-ordered route {t.coords(src)} -> {t.coords(dst)}: "
          f"{[t.coords(r) for r in t.route(src, dst)]}")

    # --- RDMA put over a mesh axis -------------------------------------------
    mesh = make_mesh((8,), ("x",))
    x = np.arange(8 * 3, dtype=np.float32).reshape(8, 3)
    shifted = jax.jit(jaxcompat.shard_map(
        lambda v: rdma.put_shift(v[0], "x", +1)[None],
        mesh=mesh, in_specs=(P("x"),), out_specs=P("x")))(x)
    print("rdma.put_shift(+1) moved every rank's row to its +X neighbour:",
          np.allclose(np.asarray(shifted), np.roll(x, 1, axis=0)))

    # --- bidirectional ring all-reduce vs psum --------------------------------
    v = np.random.default_rng(0).normal(size=(8, 1000)).astype(np.float32)
    ours = np.asarray(C.make_stacked_all_reduce(mesh, ("x",))(v))
    want = v.sum(0)
    print("bidirectional double-buffered ring all-reduce == sum:",
          np.allclose(ours, want[None], rtol=2e-5, atol=1e-5))

    # --- the paper's numbers ---------------------------------------------------
    net = apelink.NetModel()
    print("\npaper model reproduction:")
    print(f"  APElink efficiency          {apelink.protocol_efficiency():.3f}"
          "   (paper 0.784)")
    print(f"  sustained link bandwidth    "
          f"{apelink.sustained_bandwidth()/1e9:.2f} GB/s (paper ~2.2)")
    print(f"  GPU-GPU latency, P2P        "
          f"{net.latency(32, src_gpu=True, dst_gpu=True)*1e6:.1f} us "
          "(paper ~8.2)")
    print(f"  GPU-GPU latency, staged     "
          f"{net.latency(32, src_gpu=True, dst_gpu=True, p2p=False)*1e6:.1f}"
          " us (paper ~16.8)")
    print(f"  GPU-GPU latency, IB+MVAPICH "
          f"{net.latency(32, fabric='ib')*1e6:.1f} us (paper ~17.4)")
    print(f"  LO|FA|MO Ta @ WD=500ms      {awareness_time_model(0.5):.2f} s "
          "(paper 0.9)")
    print("\ntorus demo OK")


if __name__ == "__main__":
    main()
