"""Batched serving with the paged KV cache + TLB registration (paper §2.2).

  PYTHONPATH=src python examples/paged_serving.py

Continuous batching: requests arrive, claim page-granular KV slots whose
virtual->physical translation goes through the RDMA registration TLB, and
finished requests release pages for newly admitted ones.  Decode attention
dispatches through the paged-attention kernel (the in-kernel page-table
lookup is the "hardware TLB" fast path of Fig 2).
"""
import time

import numpy as np

import jax

from repro import configs
from repro.models import api
from repro.serving.engine import Engine, PagedLM, Request


def main() -> None:
    cfg = configs.get_config("qwen2-0.5b").reduced()
    model = api.get_model(cfg)
    params = model.init(jax.random.key(0))

    lm = PagedLM(cfg, params, max_batch=4, max_seq=96, page_tokens=16)
    eng = Engine(lm)
    rng = np.random.default_rng(0)
    n_requests = 10
    for rid in range(n_requests):
        plen = int(rng.integers(4, 20))
        eng.submit(Request(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab, size=(plen,)).astype(np.int32),
            max_new_tokens=int(rng.integers(4, 12))))

    t0 = time.perf_counter()
    eng.run_to_completion()
    dt = time.perf_counter() - t0
    stats = eng.stats()
    toks = sum(len(r.out_tokens) for r in eng.finished)
    print(f"finished {len(eng.finished)}/{n_requests} requests, "
          f"{toks} tokens in {dt:.2f}s")
    print(f"decode steps (continuous batching): {stats['decode_steps']}")
    print(f"TLB hit rate: {stats['tlb_hit_rate']:.3f} "
          f"(translation cost {stats['translation_cost_s']*1e6:.1f} us; "
          "a page hit bypasses the Nios II walk — Fig 2)")
    assert len(eng.finished) == n_requests
    assert stats["tlb_hit_rate"] > 0.0
    for r in eng.finished[:3]:
        print(f"  req {r.rid}: prompt[:4]={r.prompt[:4].tolist()} -> "
              f"out={r.out_tokens}")
    print("paged serving OK")


if __name__ == "__main__":
    main()
