"""Quickstart: train a small LM end-to-end on CPU with the public API.

  PYTHONPATH=src python examples/quickstart.py

Covers: config lookup, trainer construction, training with periodic
checkpoints, resuming from the checkpoint, and greedy decoding with the
trained params — the whole train->checkpoint->restore->serve loop in one
file.
"""
import tempfile

import numpy as np

import jax

from repro import configs
from repro.models import api
from repro.optim import AdamWConfig
from repro.runtime.trainer import Trainer, TrainerConfig


def main() -> None:
    # a tiny same-family variant of an assigned arch: runs in seconds on CPU
    cfg = configs.get_config("smollm-135m").reduced()
    print(f"arch={cfg.name} family={cfg.family} "
          f"layers={cfg.n_layers} d_model={cfg.d_model}")

    with tempfile.TemporaryDirectory() as ckpt_dir:
        opt = AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=60)
        tcfg = TrainerConfig(ckpt_dir=ckpt_dir, ckpt_every=20, batch=8,
                             seq_len=64, opt=opt, comm="single")
        trainer = Trainer(cfg, tcfg)
        print(f"params: {trainer.n_params:,}")

        metrics = trainer.train(40)
        losses = [m["loss"] for m in metrics]
        print(f"step  1: loss {losses[0]:.4f}")
        print(f"step 40: loss {losses[-1]:.4f}")
        assert losses[-1] < losses[0], "loss should decrease"

        # --- restart from the checkpoint (simulates a new process) -----------
        trainer2 = Trainer(cfg, tcfg)
        trainer2.resume()
        print(f"resumed at step {trainer2.data.step} "
              f"(events: {trainer2.events})")
        more = trainer2.train(10)
        assert all(np.isfinite(m["loss"]) for m in more)

        # --- greedy decode with the trained params ---------------------------
        model = api.get_model(cfg)
        params = trainer2.params
        prompt = np.array([[5, 17, 42, 7]], dtype=np.int32)
        logits, cache = model.prefill(
            params, {"tokens": jax.numpy.asarray(prompt)}, max_len=32,
            remat=False)
        tok = int(jax.numpy.argmax(logits[0, -1]))
        out = [tok]
        pos = prompt.shape[1]
        step = jax.jit(model.decode_step)
        for _ in range(8):
            logits, cache = step(params,
                                 jax.numpy.asarray([[tok]], dtype=np.int32),
                                 cache, jax.numpy.asarray(pos))
            tok = int(jax.numpy.argmax(logits[0, -1]))
            out.append(tok)
            pos += 1
        print("generated tokens:", out)
    print("quickstart OK")


if __name__ == "__main__":
    main()
