"""Fault-tolerant data-parallel training over the torus fabric (paper §4).

  PYTHONPATH=src python examples/fault_tolerant_train.py

Runs the paper-faithful "apex" communication mode (explicit bidirectional
ring reduce-scatter / all-gather over the torus, lowered through the
fabric's CollectiveSchedule IR) on 8 forced host devices, and exercises
BOTH fault-handling paths:

1. a torus LINK dies: LO|FA|MO's neighbour watchdogs each suspect the
   peer, the master correlates the two still-heartbeating endpoints into a
   link fault, and the trainer *reroutes* — the collective schedules are
   rewritten around the dead link (detour hops, higher predicted comm
   cost) and training continues with identical numerics, no restart;

2. a whole NODE dies: detection diffuses to the neighbours, the master
   flags the rank, and the trainer checkpoint-restarts on the surviving
   devices (elastic re-mesh 8 -> 4) replaying the data stream.
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import tempfile  # noqa: E402

import numpy as np  # noqa: E402

from repro import configs  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402
from repro.optim import AdamWConfig  # noqa: E402
from repro.runtime.trainer import Trainer, TrainerConfig  # noqa: E402


def main() -> None:
    cfg = configs.get_config("qwen2-0.5b").reduced()
    mesh = make_mesh((8,), ("data",))
    with tempfile.TemporaryDirectory() as ckpt_dir:
        tcfg = TrainerConfig(
            ckpt_dir=ckpt_dir, ckpt_every=5, batch=8, seq_len=32,
            opt=AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=40),
            comm="apex", dp_axis="data", fault_mode="reroute",
            wd_period=0.5)
        tr = Trainer(cfg, tcfg, mesh=mesh)
        print(f"[fabric] torus dims={tr.torus.dims}, comm=apex "
              f"(CollectiveSchedule-lowered torus ring collectives)")
        print(f"[fabric] predicted grad-sync: "
              f"{tr.predicted_comm_s * 1e3:.2f} ms/step")

        def fault_hook(i):
            if i == 2:
                print("[fault]  cutting link (2,3) ...")
                tr.lofamo.kill_link(2, 3)
            if i == 8:
                print("[fault]  killing node 5 (host+NIC) ...")
                tr.lofamo.kill_node(5)

        metrics = tr.train(16, fault_hook=fault_hook)
        losses = [m["loss"] for m in metrics]
        print(f"[train]  losses: {losses[0]:.3f} ... {losses[-1]:.3f}")
        assert all(np.isfinite(x) for x in losses)
        print("[events]")
        for e in tr.events:
            print("   ", e)
        # link fault -> reroute, no restart
        assert any("rerouted collectives" in e for e in tr.events), \
            "link reroute expected"
        # node fault -> elastic re-mesh
        assert any("re-mesh" in e for e in tr.events), "re-mesh expected"
        assert tr.mesh.devices.size == 4
        # predicted vs measured communication for the last step
        last = metrics[-1]
        print(f"[cost]   predicted comm {last['predicted_comm_s'] * 1e3:.2f}"
              f" ms vs measured step {last['step_time_s'] * 1e3:.1f} ms")
        # LO|FA|MO awareness-time model at this watchdog period
        from repro.core.lofamo import awareness_time_model
        print(f"[lofamo] Ta(WD=500ms) = {awareness_time_model(0.5):.2f} s "
              "(paper: 0.9 s)")
    print("fault-tolerant training OK "
          "(link rerouted, then 8 -> 4 devices, training continued)")


if __name__ == "__main__":
    main()
